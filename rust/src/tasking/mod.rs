//! The task-based runtime — our from-scratch PyCOMPSs substrate.
//!
//! The paper's performance claims are claims about *task graphs*: how many
//! tasks an operation emits, how wide they are, and how a master–worker
//! runtime with a per-task scheduling cost executes them. This module
//! reproduces that programming model behind a pluggable [`Executor`] trait:
//!
//! * applications (the ds-array layer, the Dataset baseline, estimators)
//!   **submit tasks** with declared reads/writes; the master infers the
//!   dependency graph and runs dependency-free tasks on workers
//!   (paper §3.1.2). [`Runtime::submit_batch`] inserts a whole slice of
//!   tasks under ONE scheduler-lock acquisition, so an N×M transpose or
//!   matmul costs one master round-trip per *operation* instead of one per
//!   *task* — the same amortization the paper credits to collection
//!   parameters (§4.2.1, §5.2);
//! * data lives behind **future handles** ([`DataId`]); handles are
//!   single-assignment (PyCOMPSs' data renaming, i.e. SSA), so the writer of
//!   an id is unique and dependencies are exactly reader-after-writer;
//! * **collection parameters** are plain multi-id reads/writes — a task may
//!   read or write arbitrarily many blocks (the PyCOMPSs
//!   `COLLECTION_IN`/`COLLECTION_OUT` feature ds-arrays exploit, §4.2.1);
//! * **block reclamation is refcounted**: the graph counts outstanding task
//!   reads and application handle references per data id (`DsArray` owns
//!   its blocks' handles — construction/`clone` retain, `Drop` releases).
//!   A fully-consumed, unpinned block is evicted from the data table, so a
//!   multi-step pipeline's resident memory is bounded by its live frontier
//!   instead of growing with the whole graph. [`Metrics`] tracks
//!   `peak_resident_bytes` and `blocks_evicted`; [`Runtime::pin`] opts a
//!   block out;
//! * **ownership-aware tasks** ([`TaskBody::Owned`]) extend reclamation
//!   into execution: at claim time, an input block that meets the eviction
//!   condition (sole outstanding reader, no handles, unpinned) is handed to
//!   the task exclusively ([`TaskInput::Owned`]) so it can mutate the
//!   buffer in place instead of allocating — the execution mode of the
//!   fused elementwise engine (`dsarray::expr`). [`Metrics`] counts
//!   `tasks_fused`, `inplace_hits` and `bytes_allocated`;
//! * **out-of-core residency** ([`Runtime::local_with_budget`]) extends
//!   reclamation from "drop dead blocks" to a full resident-set policy:
//!   a `memory_budget_bytes` high-water mark spills least-recently-used
//!   *live* blocks to a per-runtime [`crate::storage::BlockStore`]
//!   directory and task-input resolution / [`Runtime::wait`] fault them
//!   back transparently, so any pipeline runs at N× RAM (`docs/IO.md`).
//!
//! Three [`Executor`] backends share the submission API:
//! [`Runtime::local`] — a real thread-pool master–worker with per-worker
//! deques and cost-aware work stealing (see [`local`]) —
//! [`Runtime::cluster`] — a multi-**process** coordinator that distributes
//! block residency across TCP worker daemons with locality-aware task
//! placement (see [`cluster`] and `docs/CLUSTER.md`) — and
//! [`Runtime::sim`] — a discrete-event simulator that executes the *same*
//! graphs under a calibrated cluster cost model at MareNostrum scale
//! (DESIGN.md §2). [`Runtime::from_executor`] accepts any custom backend.

pub mod cluster;
pub mod faults;
pub mod graph;
pub mod local;
pub mod metrics;
pub mod ops;
pub mod sim;
pub mod task;
pub mod wire;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta};
pub use cluster::{ClusterOptions, TransferMode, WorkerOptions, HEARTBEAT_MISS_THRESHOLD};
pub use faults::{FaultKind, FaultPlan, FaultRule, FaultState};
pub use local::LocalOptions;
pub use metrics::Metrics;
pub use sim::{SimConfig, SimReport};
pub use task::{
    CostHint, DataId, OwnedTaskFn, TaskBody, TaskFn, TaskId, TaskInput, TaskSpec, TaskSubmit,
};

/// Handle to a submitted-but-possibly-unfinished block — the PyCOMPSs
/// "future object" (paper §3.1.2). Metadata is always known; the value
/// requires synchronization (and is unavailable in sim mode).
///
/// Futures are plain `Copy` handles and do not own the block: ownership is
/// tracked per-container (a `DsArray` retains its blocks on construction
/// and releases them on drop). A bare future that never enters a container
/// keeps its block resident forever — the safe default.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Future {
    pub id: DataId,
    pub meta: BlockMeta,
}

/// Pluggable execution backend behind [`Runtime`]. Implementations must be
/// thread-safe: submissions, waits and barriers arrive concurrently.
pub trait Executor: Send + Sync {
    /// Number of workers (threads or simulated cores).
    fn workers(&self) -> usize;

    /// Whether this backend only records graphs for simulation.
    fn is_sim(&self) -> bool {
        false
    }

    /// Register an already-materialized block (no task executes for it).
    fn put_block(&self, block: Block) -> DataId;

    /// Insert a slice of tasks under one scheduler-lock acquisition.
    /// Returns the output ids of each task, in submission order. Tasks may
    /// read outputs of earlier tasks in the same batch.
    fn submit_batch(&self, tasks: Vec<TaskSubmit>) -> Vec<Vec<DataId>>;

    /// Insert a batch and then drop one application handle reference per
    /// entry of `release` — atomically with respect to task claims, so a
    /// submitter that hands its inputs over to the batch (the fused
    /// elementwise engine's early release) can register its reads before
    /// the handles disappear. The default is the non-atomic sequence;
    /// executors with concurrent claim paths should override it.
    fn submit_batch_releasing(
        &self,
        tasks: Vec<TaskSubmit>,
        release: &[DataId],
    ) -> Vec<Vec<DataId>> {
        let outs = self.submit_batch(tasks);
        self.release(release);
        outs
    }

    /// Synchronize one id and return its block — `compss_wait_on`.
    fn wait(&self, id: DataId) -> Result<Arc<Block>>;

    /// Wait until every submitted task has finished.
    fn barrier(&self) -> Result<()>;

    /// Task-count, traffic and residency metrics accumulated so far.
    fn metrics(&self) -> Metrics;

    /// Add an application handle reference to each id.
    fn retain(&self, ids: &[DataId]);

    /// Drop an application handle reference from each id; fully-consumed,
    /// unpinned blocks are reclaimed.
    fn release(&self, ids: &[DataId]);

    /// Exempt an id from reclamation permanently.
    fn pin(&self, id: DataId);

    /// Replay the recorded graph through the cluster model (sim backends).
    fn run_sim(&self, _traced: bool) -> Result<SimReport> {
        bail!("run_sim on a non-simulated runtime")
    }

    /// Enroll a new worker into a running fleet (cluster backend only);
    /// returns the worker's location-table slot.
    fn join_worker(&self, _addr: &str) -> Result<usize> {
        bail!("join_worker on a non-cluster runtime")
    }

    /// Gracefully decommission worker `w` — migrate its sole-copy blocks
    /// to survivors, then drop it from the fleet (cluster backend only).
    fn drain_worker(&self, _w: usize) -> Result<()> {
        bail!("drain_worker on a non-cluster runtime")
    }

    /// Address of the coordinator's control listener, where `Join`/`Drain`
    /// frames arrive (`None` on non-cluster backends).
    fn control_addr(&self) -> Option<String> {
        None
    }
}

/// One task of a [`Runtime::submit_batch`] call, with reads still expressed
/// as [`Future`] handles (the runtime lowers them to ids and computes the
/// declared input bytes).
pub struct BatchTask {
    pub name: &'static str,
    pub reads: Vec<Future>,
    pub out_metas: Vec<BlockMeta>,
    pub hint: CostHint,
    pub body: TaskBody,
    /// Logical operations this task fuses (1 for ordinary tasks); feeds
    /// [`Metrics`]' `tasks_fused` counter.
    pub fused_ops: u32,
}

impl BatchTask {
    pub fn new(
        name: &'static str,
        reads: Vec<Future>,
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        func: TaskFn,
    ) -> Self {
        Self {
            name,
            reads,
            out_metas,
            hint,
            body: TaskBody::Shared(func),
            fused_ops: 1,
        }
    }

    /// An ownership-aware task: the executor grants exclusively-consumable
    /// inputs as [`TaskInput::Owned`] so the closure can mutate them in
    /// place (the fused elementwise engine's execution mode).
    pub fn new_owned(
        name: &'static str,
        reads: Vec<Future>,
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        func: OwnedTaskFn,
    ) -> Self {
        Self {
            name,
            reads,
            out_metas,
            hint,
            body: TaskBody::Owned(func),
            fused_ops: 1,
        }
    }

    /// Declare how many logical operations this task fuses.
    pub fn with_fused_ops(mut self, ops: u32) -> Self {
        self.fused_ops = ops.max(1);
        self
    }
}

/// The runtime handle shared by every distributed structure. Cheap to clone.
#[derive(Clone)]
pub struct Runtime {
    exec: Arc<dyn Executor>,
    /// Kernel vtable selected once per process (runtime CPU feature
    /// detection, overridable with `DSARRAY_NO_SIMD=1`) and stored here so
    /// hot paths never re-detect per task — see [`crate::kernels`].
    kernels: &'static crate::kernels::Kernels,
    /// Plan layer (CSE memo, optimization level, planner counters) shared
    /// by all clones of this runtime — see [`crate::plan`]. Legacy
    /// constructors run it at [`crate::plan::Level::Off`]; the
    /// [`Runtime::builder`] front door defaults to `Level::Full`.
    planner: Arc<crate::plan::Planner>,
}

/// A fresh planner at the legacy-default level (used by all direct
/// constructors so pre-planner task streams stay exact).
fn planner_off() -> Arc<crate::plan::Planner> {
    Arc::new(crate::plan::Planner::new(crate::plan::Level::Off))
}

impl Runtime {
    /// Real executor: `workers` OS threads execute tasks as they become
    /// dependency-free (per-worker deques, cost-aware stealing).
    ///
    /// ```
    /// use rustdslib::tasking::Runtime;
    /// let rt = Runtime::local(2);
    /// assert_eq!(rt.workers(), 2);
    /// assert!(!rt.is_sim());
    /// ```
    pub fn local(workers: usize) -> Self {
        Self {
            exec: Arc::new(local::LocalExecutor::new(workers.max(1))),
            kernels: crate::kernels::active(),
            planner: planner_off(),
        }
    }

    /// The single fluent front door over every backend and knob — local,
    /// sim, or cluster, with budgets, replication, and the plan-layer
    /// optimizer level. See [`crate::plan::RuntimeBuilder`].
    ///
    /// ```
    /// use rustdslib::plan::Level;
    /// use rustdslib::tasking::Runtime;
    /// let rt = Runtime::builder().workers(2).optimizer(Level::Cse).build().unwrap();
    /// assert_eq!(rt.planner().level(), Level::Cse);
    /// ```
    pub fn builder() -> crate::plan::RuntimeBuilder {
        crate::plan::RuntimeBuilder::new()
    }

    /// Local executor with an out-of-core **memory budget**: when the
    /// resident block payload exceeds `memory_budget_bytes`, least-recently
    /// used blocks are spilled to a per-runtime disk directory (write-back
    /// for dirty values, free drop for clean ones) and fault back in
    /// transparently when a task or [`Runtime::wait`] needs them. Every
    /// workload — including the estimators — runs unmodified at N× RAM;
    /// [`Metrics`] reports `blocks_spilled` / `blocks_faulted` /
    /// `spill_bytes`. The spill directory is removed at runtime teardown.
    ///
    /// ```
    /// use rustdslib::dsarray::creation;
    /// use rustdslib::tasking::Runtime;
    /// let rt = Runtime::local_with_budget(2, 4 * 64 * 64 * 4).unwrap(); // 4 blocks
    /// let a = creation::random(&rt, (512, 64), (64, 64), 7).unwrap(); // 8 blocks
    /// let b = a.add_scalar(1.0).unwrap().collect().unwrap(); // faults as needed
    /// assert_eq!(b.rows(), 512);
    /// assert!(rt.metrics().blocks_spilled > 0);
    /// ```
    pub fn local_with_budget(workers: usize, memory_budget_bytes: u64) -> Result<Self> {
        Self::local_with_options(LocalOptions {
            workers,
            memory_budget_bytes: Some(memory_budget_bytes),
            spill_dir: None,
        })
    }

    /// Local executor from full [`LocalOptions`] (budget + spill dir).
    /// Errors if the spill directory cannot be created.
    pub fn local_with_options(opts: LocalOptions) -> Result<Self> {
        Ok(Self {
            exec: Arc::new(local::LocalExecutor::with_options(opts)?),
            kernels: crate::kernels::active(),
            planner: planner_off(),
        })
    }

    /// Multi-process cluster executor: block payloads live on N worker
    /// **processes** reached over TCP (`dsarray worker --listen <addr>`),
    /// tasks are placed on the worker holding the most input bytes, and
    /// missing inputs move worker-to-worker (or relay through the
    /// coordinator). Spawns workers, connects to existing ones, or both —
    /// see [`ClusterOptions`]. [`Metrics`] gains `bytes_on_wire`,
    /// `remote_transfers` and `locality_hits` on this backend.
    pub fn cluster(opts: ClusterOptions) -> Result<Self> {
        Ok(Self {
            exec: Arc::new(cluster::ClusterExecutor::new(opts)?),
            kernels: crate::kernels::active(),
            planner: planner_off(),
        })
    }

    /// Simulated executor: tasks are recorded (never run) and
    /// [`Runtime::run_sim`] replays the graph through the discrete-event
    /// cluster model.
    pub fn sim(cfg: SimConfig) -> Self {
        Self {
            exec: Arc::new(sim::SimExecutor::new(cfg)),
            kernels: crate::kernels::active(),
            planner: planner_off(),
        }
    }

    /// Wrap a custom [`Executor`] backend.
    pub fn from_executor(exec: Arc<dyn Executor>) -> Self {
        Self {
            exec,
            kernels: crate::kernels::active(),
            planner: planner_off(),
        }
    }

    /// Replace this handle's planner with a fresh one at `level` (fresh
    /// memo, fresh counters). Construction-time only — clones taken
    /// *before* this call keep the old planner.
    pub fn with_optimizer(mut self, level: crate::plan::Level) -> Self {
        self.planner = Arc::new(crate::plan::Planner::new(level));
        self
    }

    /// The plan layer shared by clones of this runtime: optimization
    /// level, CSE memo, and the planner counters `metrics` folds in.
    pub fn planner(&self) -> &crate::plan::Planner {
        &self.planner
    }

    /// CSE memo lookup (see [`crate::plan::Planner::lookup`]). The memoized
    /// futures come back *without* an extra handle reference — callers wrap
    /// them in a container (`DsArray::from_parts` retains) exactly as they
    /// would wrap fresh task outputs.
    pub(crate) fn cse_lookup(&self, key: u128, tasks_avoided: u64) -> Option<Vec<Future>> {
        self.planner.lookup(key, tasks_avoided)
    }

    /// Memoize `outputs` under `key`, retaining one handle reference per
    /// block on the memo's behalf and releasing whatever entries the insert
    /// displaced. No-op at `Level::Off`.
    pub(crate) fn cse_record(&self, key: u128, outputs: &[Future]) {
        if !self.planner.cse_enabled() {
            return;
        }
        self.retain(outputs);
        let displaced = self.planner.record(key, outputs.to_vec());
        if !displaced.is_empty() {
            self.release(&displaced);
        }
    }

    /// Advance the planner's collect/barrier epoch (the CSE memo's GC
    /// generation), releasing the memo references of swept entries.
    pub(crate) fn plan_epoch_tick(&self) {
        let swept = self.planner.bump_epoch();
        if !swept.is_empty() {
            self.release(&swept);
        }
    }

    /// The process-wide kernel vtable (scalar or SIMD), selected once at
    /// first use and cached — tasks capture this reference instead of
    /// re-running feature detection per block.
    pub fn kernels(&self) -> &'static crate::kernels::Kernels {
        self.kernels
    }

    pub fn is_sim(&self) -> bool {
        self.exec.is_sim()
    }

    /// Number of workers (threads or simulated cores).
    pub fn workers(&self) -> usize {
        self.exec.workers()
    }

    /// Register an already-materialized block (no task executes for it).
    pub fn put_block(&self, block: Block) -> Future {
        let meta = block.meta();
        let id = self.exec.put_block(block);
        Future { id, meta }
    }

    /// Submit one task. `reads` are the input futures (collection reads are
    /// just long lists), `out_metas` declare the output shapes (shape
    /// inference is the submitter's job, mirroring the type/direction
    /// declarations of the `@task` decorator), `hint` feeds the simulator's
    /// cost model and the local scheduler's steal heuristic, and `f` is the
    /// actual computation over resolved blocks.
    ///
    /// Hot paths that emit many tasks should use [`Runtime::submit_batch`]:
    /// it pays the scheduler lock once per batch instead of once per task.
    pub fn submit(
        &self,
        name: &'static str,
        reads: &[Future],
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        f: TaskFn,
    ) -> Vec<Future> {
        self.submit_batch(vec![BatchTask::new(name, reads.to_vec(), out_metas, hint, f)])
            .pop()
            .expect("submit_batch returns one entry per task")
    }

    /// Submit a whole batch of tasks under one scheduler-lock acquisition.
    /// Returns each task's output futures in submission order. Graph
    /// construction is identical to equivalent serial [`Runtime::submit`]
    /// calls (ids are allocated in order), so batching is purely a
    /// throughput optimization.
    pub fn submit_batch(&self, batch: Vec<BatchTask>) -> Vec<Vec<Future>> {
        self.submit_batch_releasing(batch, &[])
    }

    /// As [`Runtime::submit_batch`], additionally dropping one application
    /// handle reference per entry of `release` under the SAME scheduler
    /// critical section. The batch's reads register before the handles go,
    /// so nothing is evicted prematurely — and claims never observe the
    /// stale handles, which is what makes in-place grants deterministic.
    pub fn submit_batch_releasing(
        &self,
        batch: Vec<BatchTask>,
        release: &[Future],
    ) -> Vec<Vec<Future>> {
        let mut metas: Vec<Vec<BlockMeta>> = Vec::with_capacity(batch.len());
        let mut subs: Vec<TaskSubmit> = Vec::with_capacity(batch.len());
        for t in batch {
            let read_ids: Vec<DataId> = t.reads.iter().map(|r| r.id).collect();
            let read_bytes: f64 = t.reads.iter().map(|r| r.meta.bytes() as f64).sum();
            metas.push(t.out_metas.clone());
            subs.push(TaskSubmit {
                name: t.name,
                reads: read_ids,
                out_metas: t.out_metas,
                hint: t.hint,
                read_bytes,
                body: t.body,
                fused_ops: t.fused_ops,
            });
        }
        let release_ids: Vec<DataId> = release.iter().map(|f| f.id).collect();
        let ids = self.exec.submit_batch_releasing(subs, &release_ids);
        ids.into_iter()
            .zip(metas)
            .map(|(ids, metas)| {
                ids.into_iter()
                    .zip(metas)
                    .map(|(id, meta)| Future { id, meta })
                    .collect()
            })
            .collect()
    }

    /// Synchronize one future and return its block — `compss_wait_on`.
    /// Errors in sim mode (simulated data has no values) and on blocks
    /// already reclaimed by refcount eviction.
    pub fn wait(&self, fut: Future) -> Result<Arc<Block>> {
        self.exec.wait(fut.id)
    }

    /// Wait until every submitted task has finished (local mode) — the
    /// explicit synchronization point of the programming model. Also
    /// advances the plan layer's CSE epoch (memo GC generation).
    pub fn barrier(&self) -> Result<()> {
        self.plan_epoch_tick();
        self.exec.barrier()
    }

    /// Run the discrete-event simulation over all recorded tasks and return
    /// the report. Errors in local mode.
    pub fn run_sim(&self) -> Result<SimReport> {
        self.exec.run_sim(false)
    }

    /// As [`Runtime::run_sim`], recording the per-task schedule for trace
    /// export (`SimReport::write_trace_csv`).
    pub fn run_sim_traced(&self) -> Result<SimReport> {
        self.exec.run_sim(true)
    }

    /// Task-count, traffic and residency metrics accumulated so far. The
    /// `simd_kernel_hits` counter is process-global (kernel dispatch happens
    /// below the executor layer) and is folded into the snapshot here.
    pub fn metrics(&self) -> Metrics {
        let mut m = self.exec.metrics();
        m.simd_kernel_hits = crate::kernels::simd_kernel_hits();
        // Plan-layer counters live on the planner (above the executor) and
        // are folded into the snapshot the same way.
        m.tasks_deduped = self.planner.tasks_deduped();
        m.blocks_prereleased = self.planner.blocks_prereleased();
        m
    }

    /// Add an application handle reference to each future's block.
    /// Containers that own blocks (e.g. `DsArray`) call this on
    /// construction and clone; see the module docs on reclamation.
    pub fn retain(&self, futs: &[Future]) {
        let ids: Vec<DataId> = futs.iter().map(|f| f.id).collect();
        self.exec.retain(&ids);
    }

    /// Drop an application handle reference from each future's block;
    /// fully-consumed, unpinned blocks are evicted from the data table.
    pub fn release(&self, futs: &[Future]) {
        let ids: Vec<DataId> = futs.iter().map(|f| f.id).collect();
        self.exec.release(&ids);
    }

    /// Exempt a block from refcount reclamation (e.g. source data that will
    /// be re-read by ad-hoc futures outside any container).
    pub fn pin(&self, fut: Future) {
        self.exec.pin(fut.id);
    }

    /// Enroll the worker listening at `addr` into a running cluster fleet
    /// and return its slot; it starts receiving tasks on the next
    /// scheduling decision. Errors on non-cluster backends.
    pub fn cluster_join(&self, addr: &str) -> Result<usize> {
        self.exec.join_worker(addr)
    }

    /// Gracefully decommission cluster worker `w`: mark it read-only,
    /// migrate its sole-copy blocks to survivors, then drop it from the
    /// fleet with zero tasks replayed. Errors on non-cluster backends.
    pub fn cluster_drain(&self, w: usize) -> Result<()> {
        self.exec.drain_worker(w)
    }

    /// The cluster coordinator's control-listener address (what
    /// `dsarray worker --join <addr>` connects to); `None` on non-cluster
    /// backends.
    pub fn cluster_control_addr(&self) -> Option<String> {
        self.exec.control_addr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;

    fn dense(v: Vec<f32>, r: usize, c: usize) -> Block {
        Block::Dense(DenseMatrix::from_vec(r, c, v).unwrap())
    }

    #[test]
    fn submit_chain_and_wait() {
        let rt = Runtime::local(2);
        let a = rt.put_block(dense(vec![1.0, 2.0], 1, 2));
        let b = rt.submit(
            "double",
            &[a],
            vec![BlockMeta::dense(1, 2)],
            CostHint::default(),
            Arc::new(|ins| {
                let m = ins[0].as_dense()?;
                Ok(vec![Block::Dense(m.map(|x| x * 2.0))])
            }),
        );
        let c = rt.submit(
            "add_one",
            &[b[0]],
            vec![BlockMeta::dense(1, 2)],
            CostHint::default(),
            Arc::new(|ins| {
                let m = ins[0].as_dense()?;
                Ok(vec![Block::Dense(m.map(|x| x + 1.0))])
            }),
        );
        let out = rt.wait(c[0]).unwrap();
        assert_eq!(out.as_dense().unwrap().data(), &[3.0, 5.0]);
        assert_eq!(rt.metrics().total_tasks(), 2);
    }

    #[test]
    fn sim_mode_records_but_never_runs() {
        let rt = Runtime::sim(SimConfig::with_workers(4));
        let a = rt.put_block(Block::Phantom(BlockMeta::dense(100, 100)));
        let out = rt.submit(
            "noop",
            &[a],
            vec![BlockMeta::dense(100, 100)],
            CostHint::flops(1e6),
            Arc::new(|_| panic!("sim mode must not execute tasks")),
        );
        assert!(rt.wait(out[0]).is_err());
        let report = rt.run_sim().unwrap();
        assert_eq!(report.tasks_executed, 1);
        assert!(report.makespan_s > 0.0);
    }

    fn scale_op(s: f32) -> TaskFn {
        Arc::new(move |ins: &[Arc<Block>]| {
            let m = ins[0].as_dense()?;
            Ok(vec![Block::Dense(m.map(|x| x * s))])
        })
    }

    /// Determinism: `submit_batch` must build a graph identical to the one
    /// equivalent serial `submit` calls build — same ids, same metrics,
    /// same values (satellite: determinism test).
    #[test]
    fn batch_and_serial_build_identical_graphs() {
        let build_serial = |rt: &Runtime| -> Vec<Future> {
            let src = rt.put_block(dense(vec![1.0, 2.0, 3.0, 4.0], 2, 2));
            let mut outs = Vec::new();
            for i in 0..16 {
                let o = rt.submit(
                    "scale",
                    &[src],
                    vec![BlockMeta::dense(2, 2)],
                    CostHint::flops(i as f64),
                    scale_op(i as f32),
                );
                outs.push(o[0]);
            }
            let fin = rt.submit(
                "merge",
                &outs,
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                Arc::new(|ins: &[Arc<Block>]| {
                    let mut acc = DenseMatrix::zeros(2, 2);
                    for b in ins {
                        acc.axpy(1.0, b.as_dense()?)?;
                    }
                    Ok(vec![Block::Dense(acc)])
                }),
            );
            outs.push(fin[0]);
            outs
        };
        let build_batched = |rt: &Runtime| -> Vec<Future> {
            let src = rt.put_block(dense(vec![1.0, 2.0, 3.0, 4.0], 2, 2));
            let batch: Vec<BatchTask> = (0..16)
                .map(|i| {
                    BatchTask::new(
                        "scale",
                        vec![src],
                        vec![BlockMeta::dense(2, 2)],
                        CostHint::flops(i as f64),
                        scale_op(i as f32),
                    )
                })
                .collect();
            let mut outs: Vec<Future> = rt
                .submit_batch(batch)
                .into_iter()
                .map(|v| v[0])
                .collect();
            let fin = rt.submit(
                "merge",
                &outs,
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                Arc::new(|ins: &[Arc<Block>]| {
                    let mut acc = DenseMatrix::zeros(2, 2);
                    for b in ins {
                        acc.axpy(1.0, b.as_dense()?)?;
                    }
                    Ok(vec![Block::Dense(acc)])
                }),
            );
            outs.push(fin[0]);
            outs
        };

        let rt_s = Runtime::local(2);
        let outs_s = build_serial(&rt_s);
        let rt_b = Runtime::local(2);
        let outs_b = build_batched(&rt_b);

        // Identical id/meta assignment...
        assert_eq!(outs_s, outs_b);
        // ...identical graph metrics...
        let (ms, mb) = (rt_s.metrics(), rt_b.metrics());
        assert_eq!(ms.tasks_by_op, mb.tasks_by_op);
        assert_eq!(ms.read_edges, mb.read_edges);
        assert_eq!(ms.write_edges, mb.write_edges);
        assert_eq!(ms.read_bytes, mb.read_bytes);
        // ...identical results.
        let vs = rt_s.wait(*outs_s.last().unwrap()).unwrap();
        let vb = rt_b.wait(*outs_b.last().unwrap()).unwrap();
        assert_eq!(vs.as_dense().unwrap(), vb.as_dense().unwrap());
    }

    /// Refcount reclamation end-to-end at the Runtime level: retained +
    /// released + consumed => evicted; pinned => kept.
    #[test]
    fn release_reclaims_consumed_blocks_pin_exempts() {
        let rt = Runtime::local(2);
        let a = rt.put_block(dense(vec![1.0; 4], 2, 2));
        let b = rt.put_block(dense(vec![2.0; 4], 2, 2));
        rt.retain(&[a, b]);
        rt.pin(b);
        let out = rt.submit(
            "consume",
            &[a, b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            Arc::new(|ins: &[Arc<Block>]| {
                let mut acc = ins[0].as_dense()?.clone();
                acc.axpy(1.0, ins[1].as_dense()?)?;
                Ok(vec![Block::Dense(acc)])
            }),
        );
        rt.barrier().unwrap();
        rt.release(&[a, b]);
        // `a` is fully consumed and unpinned: reclaimed. `b` is pinned.
        assert!(rt.wait(a).is_err());
        assert!(rt.wait(b).is_ok());
        let m = rt.metrics();
        assert_eq!(m.blocks_evicted, 1);
        assert!(m.peak_resident_bytes >= 3 * 16);
        assert_eq!(rt.wait(out[0]).unwrap().as_dense().unwrap().get(0, 0), 3.0);
    }
}
