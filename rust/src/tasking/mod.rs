//! The task-based runtime — our from-scratch PyCOMPSs substrate.
//!
//! The paper's performance claims are claims about *task graphs*: how many
//! tasks an operation emits, how wide they are, and how a master–worker
//! runtime with a per-task scheduling cost executes them. This module
//! reproduces that programming model:
//!
//! * applications (the ds-array layer, the Dataset baseline, estimators)
//!   **submit tasks** with declared reads/writes; the master infers the
//!   dependency graph and runs dependency-free tasks on workers
//!   (paper §3.1.2);
//! * data lives behind **future handles** ([`DataId`]); handles are
//!   single-assignment (PyCOMPSs' data renaming, i.e. SSA), so the writer of
//!   an id is unique and dependencies are exactly reader-after-writer;
//! * **collection parameters** are plain multi-id reads/writes — a task may
//!   read or write arbitrarily many blocks, which is the PyCOMPSs
//!   `COLLECTION_IN`/`COLLECTION_OUT` feature ds-arrays exploit (paper
//!   §4.2.1); the Dataset baseline predates it and uses bounded-arity tasks;
//! * two executors share the submission API: [`Runtime::local`] (a real
//!   thread-pool master–worker) and [`Runtime::sim`] (a discrete-event
//!   simulator that executes the *same* graphs under a calibrated cluster
//!   cost model at MareNostrum scale — DESIGN.md §2).

pub mod graph;
pub mod local;
pub mod metrics;
pub mod ops;
pub mod sim;
pub mod task;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta};
pub use metrics::Metrics;
pub use sim::{SimConfig, SimReport};
pub use task::{CostHint, DataId, TaskFn, TaskId, TaskSpec};

/// Handle to a submitted-but-possibly-unfinished block — the PyCOMPSs
/// "future object" (paper §3.1.2). Metadata is always known; the value
/// requires synchronization (and is unavailable in sim mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Future {
    pub id: DataId,
    pub meta: BlockMeta,
}

enum Exec {
    Local(local::LocalExecutor),
    Sim(sim::SimExecutor),
}

/// The runtime handle shared by every distributed structure. Cheap to clone.
#[derive(Clone)]
pub struct Runtime {
    exec: Arc<Exec>,
}

impl Runtime {
    /// Real executor: `workers` OS threads execute tasks as they become
    /// dependency-free.
    pub fn local(workers: usize) -> Self {
        Self {
            exec: Arc::new(Exec::Local(local::LocalExecutor::new(workers.max(1)))),
        }
    }

    /// Simulated executor: tasks are recorded (never run) and
    /// [`Runtime::run_sim`] replays the graph through the discrete-event
    /// cluster model.
    pub fn sim(cfg: SimConfig) -> Self {
        Self {
            exec: Arc::new(Exec::Sim(sim::SimExecutor::new(cfg))),
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(*self.exec, Exec::Sim(_))
    }

    /// Number of workers (threads or simulated cores).
    pub fn workers(&self) -> usize {
        match &*self.exec {
            Exec::Local(l) => l.workers(),
            Exec::Sim(s) => s.workers(),
        }
    }

    /// Register an already-materialized block (no task executes for it).
    pub fn put_block(&self, block: Block) -> Future {
        let meta = block.meta();
        let id = match &*self.exec {
            Exec::Local(l) => l.put_block(block),
            Exec::Sim(s) => s.put_block(block.meta()),
        };
        Future { id, meta }
    }

    /// Submit a task. `reads` are the input futures (collection reads are
    /// just long lists), `out_metas` declare the output shapes (shape
    /// inference is the submitter's job, mirroring the type/direction
    /// declarations of the `@task` decorator), `hint` feeds the simulator's
    /// cost model and `f` is the actual computation over resolved blocks.
    pub fn submit(
        &self,
        name: &'static str,
        reads: &[Future],
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        f: TaskFn,
    ) -> Vec<Future> {
        let read_ids: Vec<DataId> = reads.iter().map(|r| r.id).collect();
        let read_bytes: f64 = reads.iter().map(|r| r.meta.bytes() as f64).sum();
        let metas = out_metas.clone();
        let ids = match &*self.exec {
            Exec::Local(l) => l.submit(name, &read_ids, out_metas, hint, read_bytes, f),
            Exec::Sim(s) => s.submit(name, &read_ids, out_metas, hint, read_bytes, f),
        };
        ids.into_iter()
            .zip(metas)
            .map(|(id, meta)| Future { id, meta })
            .collect()
    }

    /// Synchronize one future and return its block — `compss_wait_on`.
    /// Errors in sim mode (simulated data has no values).
    pub fn wait(&self, fut: Future) -> Result<Arc<Block>> {
        match &*self.exec {
            Exec::Local(l) => l.wait(fut.id),
            Exec::Sim(_) => bail!("cannot synchronize data in simulation mode"),
        }
    }

    /// Wait until every submitted task has finished (local mode) — the
    /// explicit synchronization point of the programming model.
    pub fn barrier(&self) -> Result<()> {
        match &*self.exec {
            Exec::Local(l) => l.barrier(),
            Exec::Sim(_) => Ok(()), // graph replay happens in run_sim
        }
    }

    /// Run the discrete-event simulation over all recorded tasks and return
    /// the report. Errors in local mode.
    pub fn run_sim(&self) -> Result<SimReport> {
        match &*self.exec {
            Exec::Local(_) => bail!("run_sim on a local (non-simulated) runtime"),
            Exec::Sim(s) => s.run(),
        }
    }

    /// As [`Runtime::run_sim`], recording the per-task schedule for trace
    /// export (`SimReport::write_trace_csv`).
    pub fn run_sim_traced(&self) -> Result<SimReport> {
        match &*self.exec {
            Exec::Local(_) => bail!("run_sim on a local (non-simulated) runtime"),
            Exec::Sim(s) => s.run_traced(),
        }
    }

    /// Task-count and traffic metrics accumulated so far.
    pub fn metrics(&self) -> Metrics {
        match &*self.exec {
            Exec::Local(l) => l.metrics(),
            Exec::Sim(s) => s.metrics(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;

    fn dense(v: Vec<f32>, r: usize, c: usize) -> Block {
        Block::Dense(DenseMatrix::from_vec(r, c, v).unwrap())
    }

    #[test]
    fn submit_chain_and_wait() {
        let rt = Runtime::local(2);
        let a = rt.put_block(dense(vec![1.0, 2.0], 1, 2));
        let b = rt.submit(
            "double",
            &[a],
            vec![BlockMeta::dense(1, 2)],
            CostHint::default(),
            Arc::new(|ins| {
                let m = ins[0].as_dense()?;
                Ok(vec![Block::Dense(m.map(|x| x * 2.0))])
            }),
        );
        let c = rt.submit(
            "add_one",
            &[b[0]],
            vec![BlockMeta::dense(1, 2)],
            CostHint::default(),
            Arc::new(|ins| {
                let m = ins[0].as_dense()?;
                Ok(vec![Block::Dense(m.map(|x| x + 1.0))])
            }),
        );
        let out = rt.wait(c[0]).unwrap();
        assert_eq!(out.as_dense().unwrap().data(), &[3.0, 5.0]);
        assert_eq!(rt.metrics().total_tasks(), 2);
    }

    #[test]
    fn sim_mode_records_but_never_runs() {
        let rt = Runtime::sim(SimConfig::with_workers(4));
        let a = rt.put_block(Block::Phantom(BlockMeta::dense(100, 100)));
        let out = rt.submit(
            "noop",
            &[a],
            vec![BlockMeta::dense(100, 100)],
            CostHint::flops(1e6),
            Arc::new(|_| panic!("sim mode must not execute tasks")),
        );
        assert!(rt.wait(out[0]).is_err());
        let report = rt.run_sim().unwrap();
        assert_eq!(report.tasks_executed, 1);
        assert!(report.makespan_s > 0.0);
    }
}
