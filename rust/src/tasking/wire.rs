//! Length-prefixed binary protocol between the cluster coordinator and its
//! worker processes (`dsarray worker --listen <addr>`).
//!
//! Every message is one **frame**:
//!
//! ```text
//! len     u32 LE               payload byte count (excludes this field)
//! opcode  u8                   message kind (below)
//! body    len-1 bytes          opcode-specific
//! ```
//!
//! Block payloads reuse the self-describing record format of the out-of-core
//! spill store ([`crate::storage::store::write_block`]) — `DSBK` magic,
//! dense and CSR kinds — so a block travels the wire in exactly the bytes it
//! would occupy in a spill file, and the codec is tested once.
//!
//! Request opcodes (coordinator → worker, or worker → peer worker):
//!
//! | op   | name     | body                                             |
//! |------|----------|--------------------------------------------------|
//! | 0x01 | Ping     | —                                                |
//! | 0x02 | Put      | `id u32` + block record                          |
//! | 0x03 | Get      | `id u32`                                         |
//! | 0x04 | Free     | `n u32` + n × `id u32`                           |
//! | 0x05 | Pull     | `id u32` + `alen u16` + peer address (UTF-8)     |
//! | 0x06 | Stat     | —                                                |
//! | 0x07 | Shutdown | —                                                |
//! | 0x08 | Crash    | —                                                |
//! | 0x09 | Join     | `alen u16` + worker listen address (UTF-8)       |
//! | 0x0a | Drain    | `alen u16` + worker listen address (UTF-8)       |
//! | 0x0b | Predict  | `mlen u16` + model name (UTF-8) + block record   |
//!
//! Response opcodes (worker → requester):
//!
//! | op   | name          | body                                               |
//! |------|---------------|----------------------------------------------------|
//! | 0x81 | Ok            | —                                                  |
//! | 0x82 | Block         | block record                                       |
//! | 0x83 | Pulled        | `bytes u64` (wire bytes moved worker-to-worker)    |
//! | 0x84 | Stat          | `blocks u64, resident u64, spilled u64, pulled u64`|
//! | 0x85 | Err           | UTF-8 message                                      |
//! | 0x86 | PullPeerDown  | UTF-8 message                                      |
//! | 0x87 | PredictResult | block record                                       |
//! | 0x88 | Overloaded    | UTF-8 message                                      |
//!
//! `Crash` kills the worker abruptly (fault-injection testing: no response,
//! no cleanup — the nearest thing to SIGKILL that works for the in-process
//! workers tests use). `PullPeerDown` distinguishes "the peer I was told to
//! pull from is unreachable" (a transport failure of the *peer*, which the
//! coordinator must treat as that worker's death) from `Err` (the serving
//! worker is alive and answered; the request itself failed).
//!
//! `Join` and `Drain` flow the *other* way — worker → coordinator, on the
//! coordinator's control listener: `Join` announces a fresh worker's listen
//! address so it can be enrolled in a running fleet, `Drain` asks for a
//! graceful decommission (the coordinator migrates the worker's sole-copy
//! blocks to survivors and then stops scheduling on it).
//!
//! `Predict` is a client → serving-coordinator request ([`crate::serving`]):
//! the named model scores the rows of the request block. The server answers
//! `PredictResult` with one output block (rows aligned to the request rows),
//! `Overloaded` when admission control sheds the request (explicit backpressure
//! rather than OOM — the client may retry later), or `Err` for a bad request
//! (unknown model, feature-count mismatch).
//!
//! Exactly one response answers each request, in order, per connection. The
//! codec is transport-agnostic (`Read`/`Write`), so the same functions serve
//! TCP streams and in-memory buffers in tests.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::storage::store::{read_block, write_block};
use crate::storage::Block;

/// Sanity cap on a single frame (1 GiB) — a corrupt length prefix must not
/// turn into an unbounded allocation.
pub const MAX_FRAME: u32 = 1 << 30;

const OP_PING: u8 = 0x01;
const OP_PUT: u8 = 0x02;
const OP_GET: u8 = 0x03;
const OP_FREE: u8 = 0x04;
const OP_PULL: u8 = 0x05;
const OP_STAT: u8 = 0x06;
const OP_SHUTDOWN: u8 = 0x07;
const OP_CRASH: u8 = 0x08;
const OP_JOIN: u8 = 0x09;
const OP_DRAIN: u8 = 0x0a;
const OP_PREDICT: u8 = 0x0b;
const OP_OK: u8 = 0x81;
const OP_BLOCK: u8 = 0x82;
const OP_PULLED: u8 = 0x83;
const OP_STAT_R: u8 = 0x84;
const OP_ERR: u8 = 0x85;
const OP_PULL_PEER_DOWN: u8 = 0x86;
const OP_PREDICT_R: u8 = 0x87;
const OP_OVERLOADED: u8 = 0x88;

/// One coordinator/peer request to a worker.
#[derive(Debug)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// Store `block` under `id` (overwrites any previous value).
    Put { id: u32, block: Block },
    /// Return the block stored under `id`.
    Get { id: u32 },
    /// Drop the listed blocks (refcount reclamation's remote free).
    Free { ids: Vec<u32> },
    /// Fetch `id` from the worker listening at `from` and store it locally
    /// (worker-to-worker pull; the source keeps its copy — blocks are
    /// single-assignment, so replicas never go stale).
    Pull { id: u32, from: String },
    /// Report block count / resident bytes / spill and pull counters.
    Stat,
    /// Clean up (remove the spill directory) and exit the worker process.
    Shutdown,
    /// Die abruptly, SIGKILL-style: no response, no cleanup. Fault-injection
    /// testing only — this is how tests kill an in-process worker that
    /// shares the test's OS process.
    Crash,
    /// Worker → coordinator (control listener): enroll the worker listening
    /// at `addr` into the running fleet. Answered `Ok` once enrolled.
    Join { addr: String },
    /// Worker → coordinator (control listener): decommission the worker
    /// listening at `addr` gracefully — stop scheduling on it, migrate its
    /// sole-copy blocks to survivors, then drop it from the fleet. Answered
    /// `Ok` once the drain completes (the worker may then exit).
    Drain { addr: String },
    /// Client → serving coordinator ([`crate::serving`]): score the rows of
    /// `block` with the model registered under `model`. Answered
    /// [`Response::PredictResult`], [`Response::Overloaded`] (shed by
    /// admission control), or [`Response::Err`].
    Predict { model: String, block: Block },
}

/// Worker-side counters returned by [`Request::Stat`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStat {
    /// Blocks currently stored (in memory or spilled).
    pub blocks: u64,
    /// Payload bytes currently resident in worker memory.
    pub resident_bytes: u64,
    /// Blocks pushed to this worker's spill store by its memory budget.
    pub blocks_spilled: u64,
    /// Wire bytes this worker fetched from peers via [`Request::Pull`].
    pub pulled_bytes: u64,
}

/// One worker reply.
#[derive(Debug)]
pub enum Response {
    Ok,
    Block(Block),
    Pulled { bytes: u64 },
    Stat(WorkerStat),
    Err(String),
    /// A `Pull`'s *peer* was unreachable (connect/transport failure). The
    /// responding worker is healthy; the peer must be presumed dead.
    PullPeerDown(String),
    /// A `Predict`'s answer: one block whose rows align with the request's.
    PredictResult(Block),
    /// A `Predict` shed by admission control — the serving tier is at its
    /// configured pending-row budget. Explicit backpressure: retry later.
    Overloaded(String),
}

fn push_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Encode a `alen u16` + UTF-8 address field (Pull/Join/Drain bodies).
fn push_addr(buf: &mut Vec<u8>, addr: &str) -> Result<()> {
    let a = addr.as_bytes();
    if a.len() > u16::MAX as usize {
        bail!("address of {} bytes is not addressable", a.len());
    }
    push_u16(buf, a.len() as u16);
    buf.extend_from_slice(a);
    Ok(())
}

/// Cursor over a received payload; every read is bounds-checked so a
/// truncated or malicious frame errors instead of panicking.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated frame: wanted {n} bytes at offset {}, payload is {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    /// Decode a `alen u16` + UTF-8 address field (Pull/Join/Drain bodies).
    fn addr(&mut self) -> Result<String> {
        let alen = self.u16()? as usize;
        String::from_utf8(self.take(alen)?.to_vec()).context("address is not UTF-8")
    }
}

/// Write one frame; returns the total bytes written (header + payload).
fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<u64> {
    // Checked BEFORE the u32 cast: a >= 4 GiB payload must error, not wrap
    // into a small header that desyncs the stream.
    if payload.len() > MAX_FRAME as usize {
        bail!("frame of {} bytes exceeds MAX_FRAME", payload.len());
    }
    let len = payload.len() as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(4 + payload.len() as u64)
}

/// Read one frame payload; returns (payload, total bytes read).
fn read_frame(r: &mut impl Read) -> Result<(Vec<u8>, u64)> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr);
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME — corrupt stream?");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok((payload, 4 + len as u64))
}

/// Serialize and send one request; returns the bytes written.
pub fn write_request(w: &mut impl Write, req: &Request) -> Result<u64> {
    let mut buf = Vec::new();
    match req {
        Request::Ping => buf.push(OP_PING),
        Request::Put { id, block } => {
            buf.push(OP_PUT);
            push_u32(&mut buf, *id);
            write_block(&mut buf, block).context("encoding Put block record")?;
        }
        Request::Get { id } => {
            buf.push(OP_GET);
            push_u32(&mut buf, *id);
        }
        Request::Free { ids } => {
            buf.push(OP_FREE);
            push_u32(&mut buf, ids.len() as u32);
            for &id in ids {
                push_u32(&mut buf, id);
            }
        }
        Request::Pull { id, from } => {
            buf.push(OP_PULL);
            push_u32(&mut buf, *id);
            push_addr(&mut buf, from)?;
        }
        Request::Stat => buf.push(OP_STAT),
        Request::Shutdown => buf.push(OP_SHUTDOWN),
        Request::Crash => buf.push(OP_CRASH),
        Request::Join { addr } => {
            buf.push(OP_JOIN);
            push_addr(&mut buf, addr)?;
        }
        Request::Drain { addr } => {
            buf.push(OP_DRAIN);
            push_addr(&mut buf, addr)?;
        }
        Request::Predict { model, block } => {
            buf.push(OP_PREDICT);
            push_addr(&mut buf, model)?;
            write_block(&mut buf, block).context("encoding Predict block record")?;
        }
    }
    write_frame(w, &buf)
}

/// Receive and decode one request.
pub fn read_request(r: &mut impl Read) -> Result<Request> {
    let (payload, _) = read_frame(r)?;
    let mut c = Cursor::new(&payload);
    let op = c.take(1)?[0];
    Ok(match op {
        OP_PING => Request::Ping,
        OP_PUT => {
            let id = c.u32()?;
            let mut rest = c.rest();
            let block = read_block(&mut rest).context("decoding Put block record")?;
            Request::Put { id, block }
        }
        OP_GET => Request::Get { id: c.u32()? },
        OP_FREE => {
            let n = c.u32()? as usize;
            let mut ids = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                ids.push(c.u32()?);
            }
            Request::Free { ids }
        }
        OP_PULL => {
            let id = c.u32()?;
            let from = c.addr()?;
            Request::Pull { id, from }
        }
        OP_STAT => Request::Stat,
        OP_SHUTDOWN => Request::Shutdown,
        OP_CRASH => Request::Crash,
        OP_JOIN => Request::Join { addr: c.addr()? },
        OP_DRAIN => Request::Drain { addr: c.addr()? },
        OP_PREDICT => {
            let model = c.addr()?;
            let mut rest = c.rest();
            let block = read_block(&mut rest).context("decoding Predict block record")?;
            Request::Predict { model, block }
        }
        other => bail!("unknown request opcode 0x{other:02x}"),
    })
}

/// Serialize and send one response; returns the bytes written.
pub fn write_response(w: &mut impl Write, resp: &Response) -> Result<u64> {
    let mut buf = Vec::new();
    match resp {
        Response::Ok => buf.push(OP_OK),
        Response::Block(block) => {
            buf.push(OP_BLOCK);
            write_block(&mut buf, block).context("encoding Block response")?;
        }
        Response::Pulled { bytes } => {
            buf.push(OP_PULLED);
            push_u64(&mut buf, *bytes);
        }
        Response::Stat(s) => {
            buf.push(OP_STAT_R);
            push_u64(&mut buf, s.blocks);
            push_u64(&mut buf, s.resident_bytes);
            push_u64(&mut buf, s.blocks_spilled);
            push_u64(&mut buf, s.pulled_bytes);
        }
        Response::Err(msg) => {
            buf.push(OP_ERR);
            buf.extend_from_slice(msg.as_bytes());
        }
        Response::PullPeerDown(msg) => {
            buf.push(OP_PULL_PEER_DOWN);
            buf.extend_from_slice(msg.as_bytes());
        }
        Response::PredictResult(block) => {
            buf.push(OP_PREDICT_R);
            write_block(&mut buf, block).context("encoding PredictResult block record")?;
        }
        Response::Overloaded(msg) => {
            buf.push(OP_OVERLOADED);
            buf.extend_from_slice(msg.as_bytes());
        }
    }
    write_frame(w, &buf)
}

/// Receive and decode one response; returns it with the bytes read (frame
/// header included) so callers can account `bytes_on_wire` exactly.
pub fn read_response(r: &mut impl Read) -> Result<(Response, u64)> {
    let (payload, n) = read_frame(r)?;
    let mut c = Cursor::new(&payload);
    let op = c.take(1)?[0];
    let resp = match op {
        OP_OK => Response::Ok,
        OP_BLOCK => {
            let mut rest = c.rest();
            Response::Block(read_block(&mut rest).context("decoding Block response")?)
        }
        OP_PULLED => Response::Pulled { bytes: c.u64()? },
        OP_STAT_R => Response::Stat(WorkerStat {
            blocks: c.u64()?,
            resident_bytes: c.u64()?,
            blocks_spilled: c.u64()?,
            pulled_bytes: c.u64()?,
        }),
        OP_ERR => Response::Err(String::from_utf8_lossy(c.rest()).into_owned()),
        OP_PULL_PEER_DOWN => {
            Response::PullPeerDown(String::from_utf8_lossy(c.rest()).into_owned())
        }
        OP_PREDICT_R => {
            let mut rest = c.rest();
            Response::PredictResult(
                read_block(&mut rest).context("decoding PredictResult block record")?,
            )
        }
        OP_OVERLOADED => Response::Overloaded(String::from_utf8_lossy(c.rest()).into_owned()),
        other => bail!("unknown response opcode 0x{other:02x}"),
    };
    Ok((resp, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{CsrMatrix, DenseMatrix};

    fn round_trip_request(req: &Request) -> Request {
        let mut buf = Vec::new();
        let written = write_request(&mut buf, req).unwrap();
        assert_eq!(written as usize, buf.len());
        let back = read_request(&mut buf.as_slice()).unwrap();
        // The whole frame must be consumed.
        back
    }

    fn round_trip_response(resp: &Response) -> Response {
        let mut buf = Vec::new();
        let written = write_response(&mut buf, resp).unwrap();
        assert_eq!(written as usize, buf.len());
        let (back, read) = read_response(&mut buf.as_slice()).unwrap();
        assert_eq!(read as usize, buf.len());
        back
    }

    #[test]
    fn dense_put_round_trips_bit_for_bit() {
        let m = DenseMatrix::from_fn(7, 5, |i, j| i as f32 * 0.25 - j as f32);
        let req = Request::Put {
            id: 42,
            block: Block::Dense(m.clone()),
        };
        match round_trip_request(&req) {
            Request::Put { id, block } => {
                assert_eq!(id, 42);
                assert_eq!(block.as_dense().unwrap(), &m);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn csr_block_response_round_trips() {
        let m = CsrMatrix::from_triplets(4, 6, &[(0, 5, 1.5), (2, 0, -2.0), (3, 3, 0.25)])
            .unwrap();
        match round_trip_response(&Response::Block(Block::Csr(m.clone()))) {
            Response::Block(b) => assert_eq!(b.as_csr().unwrap(), &m),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn control_messages_round_trip() {
        assert!(matches!(round_trip_request(&Request::Ping), Request::Ping));
        assert!(matches!(round_trip_request(&Request::Stat), Request::Stat));
        assert!(matches!(
            round_trip_request(&Request::Shutdown),
            Request::Shutdown
        ));
        match round_trip_request(&Request::Get { id: 7 }) {
            Request::Get { id } => assert_eq!(id, 7),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_request(&Request::Free {
            ids: vec![1, 2, 1000],
        }) {
            Request::Free { ids } => assert_eq!(ids, vec![1, 2, 1000]),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_request(&Request::Pull {
            id: 9,
            from: "127.0.0.1:7401".into(),
        }) {
            Request::Pull { id, from } => {
                assert_eq!(id, 9);
                assert_eq!(from, "127.0.0.1:7401");
            }
            other => panic!("decoded {other:?}"),
        }
        match round_trip_response(&Response::Pulled { bytes: 12345 }) {
            Response::Pulled { bytes } => assert_eq!(bytes, 12345),
            other => panic!("decoded {other:?}"),
        }
        let stat = WorkerStat {
            blocks: 3,
            resident_bytes: 4096,
            blocks_spilled: 1,
            pulled_bytes: 2048,
        };
        match round_trip_response(&Response::Stat(stat)) {
            Response::Stat(s) => assert_eq!(s, stat),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_response(&Response::Err("boom at 127.0.0.1:1".into())) {
            Response::Err(m) => assert_eq!(m, "boom at 127.0.0.1:1"),
            other => panic!("decoded {other:?}"),
        }
        assert!(matches!(round_trip_response(&Response::Ok), Response::Ok));
        assert!(matches!(
            round_trip_request(&Request::Crash),
            Request::Crash
        ));
        match round_trip_response(&Response::PullPeerDown("peer 127.0.0.1:2 gone".into())) {
            Response::PullPeerDown(m) => assert_eq!(m, "peer 127.0.0.1:2 gone"),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_request(&Request::Join {
            addr: "127.0.0.1:7403".into(),
        }) {
            Request::Join { addr } => assert_eq!(addr, "127.0.0.1:7403"),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_request(&Request::Drain {
            addr: "127.0.0.1:7401".into(),
        }) {
            Request::Drain { addr } => assert_eq!(addr, "127.0.0.1:7401"),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_response(&Response::Overloaded("pending rows at budget".into())) {
            Response::Overloaded(m) => assert_eq!(m, "pending rows at budget"),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn predict_frames_round_trip_bit_for_bit() {
        let rows = DenseMatrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32 * 0.5 - 1.0);
        match round_trip_request(&Request::Predict {
            model: "kmeans-prod".into(),
            block: Block::Dense(rows.clone()),
        }) {
            Request::Predict { model, block } => {
                assert_eq!(model, "kmeans-prod");
                assert_eq!(block.as_dense().unwrap(), &rows);
            }
            other => panic!("decoded {other:?}"),
        }
        let out = DenseMatrix::from_fn(3, 1, |i, _| i as f32);
        match round_trip_response(&Response::PredictResult(Block::Dense(out.clone()))) {
            Response::PredictResult(b) => assert_eq!(b.as_dense().unwrap(), &out),
            other => panic!("decoded {other:?}"),
        }
        // Truncated Predict body: decode errors, never panics.
        let mut buf = Vec::new();
        write_request(
            &mut buf,
            &Request::Predict {
                model: "m".into(),
                block: Block::Dense(rows),
            },
        )
        .unwrap();
        assert!(read_request(&mut &buf[..buf.len() - 3]).is_err());
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        let mut buf = Vec::new();
        write_request(&mut buf, &Request::Get { id: 3 }).unwrap();
        // Chop the payload: decode must error, not panic.
        assert!(read_request(&mut &buf[..buf.len() - 2]).is_err());
        // A length prefix past MAX_FRAME is rejected before allocating.
        let huge = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_request(&mut huge.as_slice()).is_err());
        // Unknown opcode.
        let mut bad = Vec::new();
        write_frame(&mut bad, &[0x7f]).unwrap();
        assert!(read_request(&mut bad.as_slice()).is_err());
        assert!(read_response(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn frames_round_trip_over_a_real_tcp_stream() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Echo server: Get{id} is answered with a 1x1 dense block of id.
            loop {
                match read_request(&mut s) {
                    Ok(Request::Get { id }) => {
                        let b = Block::Dense(DenseMatrix::full(1, 1, id as f32));
                        write_response(&mut s, &Response::Block(b)).unwrap();
                    }
                    Ok(Request::Shutdown) => {
                        write_response(&mut s, &Response::Ok).unwrap();
                        return;
                    }
                    Ok(_) => write_response(&mut s, &Response::Err("unexpected".into()))
                        .map(|_| ())
                        .unwrap(),
                    Err(_) => return, // connection closed
                }
            }
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        for id in [0u32, 9, 1000] {
            write_request(&mut c, &Request::Get { id }).unwrap();
            match read_response(&mut c).unwrap().0 {
                Response::Block(b) => {
                    assert_eq!(b.as_dense().unwrap().get(0, 0), id as f32)
                }
                other => panic!("got {other:?}"),
            }
        }
        write_request(&mut c, &Request::Shutdown).unwrap();
        assert!(matches!(read_response(&mut c).unwrap().0, Response::Ok));
        server.join().unwrap();
    }
}
