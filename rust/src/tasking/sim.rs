//! Discrete-event cluster simulator — the MareNostrum 4 substitute.
//!
//! This container has a single physical core, so the paper's 48–1536-core
//! experiments cannot run for real. Instead, the *same library code* builds
//! its real task graphs against a sim-mode [`super::Runtime`] (with phantom
//! blocks for data too large to materialize) and this executor replays the
//! graph through a calibrated model of a PyCOMPSs-style cluster:
//!
//! * a **serialized master** pays a per-task dispatch cost that grows mildly
//!   with the number of cores (the paper states "PyCOMPSs scheduling
//!   overhead is proportional to the number of cores and tasks", §5.2) plus
//!   a per-parameter (edge) cost;
//! * **workers** pay a fixed per-task overhead, a per-input parameter
//!   processing cost (serialization/IPC — this is what makes very
//!   fine-grained graphs expensive), transfer time for remote inputs
//!   (latency + bytes/bandwidth), and compute time from the task's FLOP
//!   hint;
//! * tasks are list-scheduled FIFO in readiness order onto the
//!   earliest-free worker.
//!
//! Calibration (DESIGN.md §6): the master constants are fitted to the two
//! hard numbers the paper reports for transpose (Dataset 4.5 h at 48 cores
//! strong / 1.5 h at 768 cores weak) and validated against the other three
//! experiments' qualitative shapes.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::storage::{Block, BlockMeta};

use super::graph::{Graph, TaskState};
use super::metrics::Metrics;
use super::task::{CostHint, DataId, TaskFn, TaskId, TaskSubmit};
use super::Executor;

/// Cluster cost model + core count. All times in seconds, rates in per-sec.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Simulated worker cores.
    pub workers: usize,
    /// Base master dispatch cost per task.
    pub sched_task_s: f64,
    /// Master dispatch grows as `sched_task_s * (1 + workers/core_scale)`.
    pub core_scale: f64,
    /// Master cost per task input/output parameter (dependency analysis).
    pub sched_edge_s: f64,
    /// Worker fixed overhead per task (spawn/teardown).
    pub task_overhead_s: f64,
    /// Worker cost per input parameter (deserialize/IPC).
    pub per_input_s: f64,
    /// Network latency per remote input object.
    pub transfer_latency_s: f64,
    /// Per-worker effective network bandwidth, bytes/s.
    pub bandwidth_bps: f64,
    /// Per-worker effective compute rate, FLOP/s.
    pub flops_per_s: f64,
    /// Per-worker effective memory streaming rate for data-movement tasks.
    pub mem_bps: f64,
}

impl SimConfig {
    /// MareNostrum 4 calibration (see module docs).
    pub fn marenostrum(workers: usize) -> Self {
        Self {
            workers,
            sched_task_s: 6.4e-3,
            core_scale: 2000.0,
            sched_edge_s: 1.5e-4,
            task_overhead_s: 1.5e-3,
            per_input_s: 2.0e-2,
            transfer_latency_s: 5.0e-4,
            bandwidth_bps: 1.0e9,
            flops_per_s: 2.0e9,
            mem_bps: 3.0e9,
        }
    }

    /// Small fast model for unit tests.
    pub fn with_workers(workers: usize) -> Self {
        Self::marenostrum(workers)
    }

    /// Effective master dispatch cost per task at this core count.
    pub fn master_task_s(&self) -> f64 {
        self.sched_task_s * (1.0 + self.workers as f64 / self.core_scale)
    }
}

/// One scheduled task in the simulated timeline (for trace export — the
/// Paraver-style view PyCOMPSs users get from Extrae).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    pub worker: u32,
    pub start_s: f64,
    pub end_s: f64,
}

/// Outcome of a simulated run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub makespan_s: f64,
    pub tasks_executed: usize,
    /// Total serialized master time (dispatch + dependency analysis).
    pub master_busy_s: f64,
    /// Sum of worker task time (overhead + inputs + transfer + compute).
    pub worker_busy_s: f64,
    /// Pure compute part of worker time.
    pub compute_s: f64,
    pub bytes_transferred: f64,
    /// worker_busy / (makespan * workers).
    pub utilization: f64,
    /// Longest dependency chain (tasks).
    pub critical_path: usize,
    /// Per-task schedule, present when the run was started with
    /// [`SimExecutor::run_traced`]. Ordered by dispatch.
    pub trace: Vec<TraceEvent>,
}

impl SimReport {
    /// Write the trace as CSV (`name,worker,start_s,end_s`).
    pub fn write_trace_csv(&self, path: &std::path::Path) -> Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "task,worker,start_s,end_s")?;
        for e in &self.trace {
            writeln!(f, "{},{},{:.6},{:.6}", e.name, e.worker, e.start_s, e.end_s)?;
        }
        Ok(())
    }
}

impl SimReport {
    /// Speedup of `other` over `self` (self_time / other_time).
    pub fn speedup_over(&self, other: &SimReport) -> f64 {
        self.makespan_s / other.makespan_s
    }
}

struct SimState {
    graph: Graph,
    metrics: Metrics,
    /// Ready at submission time (no pending deps).
    initially_ready: Vec<TaskId>,
}

pub struct SimExecutor {
    cfg: SimConfig,
    state: Mutex<SimState>,
}

/// Min-heap item: task completion event.
struct Event {
    time: f64,
    seq: u64,
    tid: TaskId,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap via BinaryHeap (max-heap).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl SimExecutor {
    pub fn new(cfg: SimConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(SimState {
                graph: Graph::default(),
                metrics: Metrics::default(),
                initially_ready: Vec::new(),
            }),
        }
    }

    /// Register a metadata-only block (phantom data).
    pub fn put_meta(&self, meta: BlockMeta) -> DataId {
        let mut st = self.state.lock().unwrap();
        st.graph.put_block(meta, None)
    }

    /// Single-task convenience wrapper used by unit tests; the library goes
    /// through [`Executor::submit_batch`].
    pub fn submit(
        &self,
        name: &'static str,
        reads: &[DataId],
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        read_bytes: f64,
        f: TaskFn,
    ) -> Vec<DataId> {
        self.submit_batch(vec![TaskSubmit {
            name,
            reads: reads.to_vec(),
            out_metas,
            hint,
            read_bytes,
            body: crate::tasking::task::TaskBody::Shared(f),
            fused_ops: 1,
        }])
        .pop()
        .expect("one entry per task")
    }

    /// Replay every recorded task through the cluster model.
    pub fn run(&self) -> Result<SimReport> {
        self.run_inner(false)
    }

    /// As [`run`], additionally recording the per-task schedule.
    pub fn run_traced(&self) -> Result<SimReport> {
        self.run_inner(true)
    }
}

impl Executor for SimExecutor {
    fn workers(&self) -> usize {
        self.cfg.workers
    }

    fn is_sim(&self) -> bool {
        true
    }

    fn put_block(&self, block: Block) -> DataId {
        // Only metadata is recorded: phantom and real blocks alike.
        self.put_meta(block.meta())
    }

    fn submit_batch(&self, tasks: Vec<TaskSubmit>) -> Vec<Vec<DataId>> {
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        let mut outs_all = Vec::with_capacity(tasks.len());
        for t in tasks {
            let (tid, outs, ready) = st.graph.submit_record(t, &mut st.metrics);
            if ready {
                st.initially_ready.push(tid);
            }
            outs_all.push(outs);
        }
        outs_all
    }

    fn wait(&self, _id: DataId) -> Result<Arc<Block>> {
        bail!("cannot synchronize data in simulation mode")
    }

    fn barrier(&self) -> Result<()> {
        Ok(()) // graph replay happens in run_sim
    }

    fn metrics(&self) -> Metrics {
        self.state.lock().unwrap().metrics.clone()
    }

    // Simulated data has no values: handle refcounts are irrelevant.
    fn retain(&self, _ids: &[DataId]) {}
    fn release(&self, _ids: &[DataId]) {}
    fn pin(&self, _id: DataId) {}

    fn run_sim(&self, traced: bool) -> Result<SimReport> {
        self.run_inner(traced)
    }
}

impl SimExecutor {
    fn run_inner(&self, traced: bool) -> Result<SimReport> {
        let mut st = self.state.lock().unwrap();
        let cfg = self.cfg.clone();
        let n_tasks = st.graph.tasks.len();
        let n_workers = cfg.workers.max(1);
        let master_task = cfg.master_task_s();

        // Data locations: worker index. Pre-existing blocks (`put_block` —
        // data already loaded, like dislib after a parallel load) are
        // distributed round-robin; task outputs live where they ran.
        let mut location: Vec<u32> = vec![0; st.graph.data.len()];
        for (i, d) in st.graph.data.iter().enumerate() {
            location[i] = match d.producer {
                None => (i % n_workers) as u32,
                Some(_) => u32::MAX, // set on completion
            };
        }

        let mut worker_free = vec![0.0f64; n_workers];
        let mut master_free = 0.0f64;
        let mut master_busy = 0.0f64;
        let mut worker_busy = 0.0f64;
        let mut compute_total = 0.0f64;
        let mut bytes_transferred = 0.0f64;
        let mut makespan = 0.0f64;
        let mut executed = 0usize;
        let mut trace: Vec<TraceEvent> = Vec::new();

        let mut events: BinaryHeap<Event> = BinaryHeap::new();
        let mut seq = 0u64;
        // Reused per-dispatch scratch (§Perf: no allocation in the loop).
        let mut tally: Vec<(u32, f64)> = Vec::with_capacity(n_workers.min(64));
        // FIFO master queue of (ready_time, task).
        let mut queue: VecDeque<(f64, TaskId)> = VecDeque::with_capacity(1024);
        for &t in &st.initially_ready {
            queue.push_back((0.0, t));
        }

        loop {
            if let Some((ready_t, tid)) = queue.pop_front() {
                // ---- Master dispatch (serialized) ----
                let node = &st.graph.tasks[tid as usize];
                let edges = node.spec.reads.len() + node.spec.writes.len();
                let m_cost = master_task + edges as f64 * cfg.sched_edge_s;
                let dispatch_end = master_free.max(ready_t) + m_cost;
                master_free = dispatch_end;
                master_busy += m_cost;

                // ---- Worker selection: locality-preferring (PyCOMPSs'
                // scheduler is locality-aware). Take the worker holding the
                // most input bytes if it is free by dispatch time;
                // otherwise fall back to the earliest-free worker.
                let (w_free, _) = worker_free
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(Ordering::Equal))
                    .unwrap();
                let w = {
                    // Tally input bytes per holding worker (distinct
                    // locations are few; linear scan is fine).
                    tally.clear();
                    for &r in node.spec.reads.iter() {
                        let loc = location[r as usize];
                        if loc == u32::MAX {
                            continue;
                        }
                        let b = st.graph.data[r as usize].meta.bytes() as f64;
                        match tally.iter_mut().find(|(l, _)| *l == loc) {
                            Some((_, acc)) => *acc += b,
                            None => tally.push((loc, b)),
                        }
                    }
                    let cand = tally
                        .iter()
                        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal))
                        .map(|&(l, _)| l as usize);
                    match cand {
                        Some(c) if worker_free[c] <= dispatch_end => c,
                        _ => w_free,
                    }
                };
                let start = dispatch_end.max(worker_free[w]);

                // ---- Worker-side costs ----
                let mut transfer = 0.0f64;
                let mut remote = 0usize;
                for &r in node.spec.reads.iter() {
                    if location[r as usize] != w as u32 {
                        remote += 1;
                        transfer += st.graph.data[r as usize].meta.bytes() as f64;
                    }
                }
                bytes_transferred += transfer;
                let t_transfer =
                    remote as f64 * cfg.transfer_latency_s + transfer / cfg.bandwidth_bps;
                let t_inputs = node.spec.reads.len() as f64 * cfg.per_input_s;
                let moved = node.spec.read_bytes
                    + node.spec.write_bytes
                    + node.spec.hint.extra_bytes;
                let t_compute = node.spec.hint.flops / cfg.flops_per_s + moved / cfg.mem_bps;
                let dur = cfg.task_overhead_s + t_inputs + t_transfer + t_compute;
                let end = start + dur;
                worker_free[w] = end;
                worker_busy += dur;
                compute_total += t_compute;
                makespan = makespan.max(end);
                executed += 1;
                if traced {
                    trace.push(TraceEvent {
                        name: node.spec.name,
                        worker: w as u32,
                        start_s: start,
                        end_s: end,
                    });
                }

                for &o in node.spec.writes.iter() {
                    location[o as usize] = w as u32;
                }
                st.graph.tasks[tid as usize].state = TaskState::Running;
                events.push(Event {
                    time: end,
                    seq,
                    tid,
                });
                seq += 1;
            } else if let Some(ev) = events.pop() {
                let now_ready = st.graph.complete(ev.tid, None).now_ready;
                for t in now_ready {
                    queue.push_back((ev.time, t));
                }
            } else {
                break;
            }
        }

        let stuck = st
            .graph
            .tasks
            .iter()
            .filter(|t| t.state == TaskState::Pending)
            .count();
        anyhow::ensure!(stuck == 0, "simulation left {stuck} tasks pending");
        anyhow::ensure!(executed == n_tasks, "executed {executed} of {n_tasks}");

        Ok(SimReport {
            makespan_s: makespan,
            tasks_executed: executed,
            master_busy_s: master_busy,
            worker_busy_s: worker_busy,
            compute_s: compute_total,
            bytes_transferred,
            utilization: if makespan > 0.0 {
                worker_busy / (makespan * n_workers as f64)
            } else {
                0.0
            },
            critical_path: st.graph.critical_path_len(),
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn noop() -> TaskFn {
        Arc::new(|_| Ok(vec![]))
    }

    fn meta() -> BlockMeta {
        BlockMeta::dense(16, 16)
    }

    fn submit_chain(ex: &SimExecutor, len: usize) -> DataId {
        let mut cur = ex.put_meta(meta());
        for _ in 0..len {
            cur = ex.submit(
                "link",
                &[cur],
                vec![meta()],
                CostHint::flops(1e6),
                1024.0,
                noop(),
            )[0];
        }
        cur
    }

    #[test]
    fn chain_makespan_at_least_critical_path_compute() {
        let ex = SimExecutor::new(SimConfig::with_workers(8));
        submit_chain(&ex, 50);
        let r = ex.run().unwrap();
        assert_eq!(r.tasks_executed, 50);
        assert_eq!(r.critical_path, 50);
        // A 50-deep chain cannot run faster than 50 sequential tasks.
        let per_task_min = 1e6 / ex.cfg.flops_per_s;
        assert!(r.makespan_s >= 50.0 * per_task_min);
    }

    #[test]
    fn wide_graph_scales_with_workers_until_master_bound() {
        let mk = |workers| {
            let ex = SimExecutor::new(SimConfig::with_workers(workers));
            let src = ex.put_meta(meta());
            for _ in 0..512 {
                ex.submit(
                    "wide",
                    &[src],
                    vec![meta()],
                    CostHint::flops(2e8), // 100ms of compute each
                    1024.0,
                    noop(),
                );
            }
            ex.run().unwrap()
        };
        let r1 = mk(1);
        let r8 = mk(8);
        let r64 = mk(64);
        assert!(r1.makespan_s > r8.makespan_s);
        assert!(r8.makespan_s > r64.makespan_s);
        // Serialized master bounds everything: makespan >= n * dispatch.
        let cfg = SimConfig::with_workers(64);
        assert!(r64.makespan_s >= 512.0 * cfg.master_task_s());
    }

    #[test]
    fn master_cost_grows_with_cores() {
        let a = SimConfig::with_workers(48).master_task_s();
        let b = SimConfig::with_workers(768).master_task_s();
        assert!(b > a);
        assert!(b / a < 2.0, "growth should be mild: {}", b / a);
    }

    #[test]
    fn remote_inputs_cost_transfers() {
        // A task reading two blocks pre-placed on different workers must
        // pull at least one of them over the network.
        let ex = SimExecutor::new(SimConfig::with_workers(2));
        let a = ex.put_meta(BlockMeta::dense(1000, 1000)); // worker 0, 4MB
        let b = ex.put_meta(BlockMeta::dense(1000, 1000)); // worker 1, 4MB
        ex.submit("c", &[a, b], vec![meta()], CostHint::default(), 8e6, noop());
        let r = ex.run().unwrap();
        assert!(r.bytes_transferred >= 4e6, "moved {}", r.bytes_transferred);
    }

    #[test]
    fn locality_avoids_transfer_for_local_reads() {
        // Single block on worker 0; an idle cluster should schedule its
        // reader on worker 0 and move zero bytes.
        let ex = SimExecutor::new(SimConfig::with_workers(4));
        let a = ex.put_meta(BlockMeta::dense(1000, 1000));
        ex.submit("c", &[a], vec![meta()], CostHint::default(), 4e6, noop());
        let r = ex.run().unwrap();
        assert_eq!(r.bytes_transferred, 0.0);
    }

    #[test]
    fn trace_records_schedule() {
        let ex = SimExecutor::new(SimConfig::with_workers(3));
        submit_chain(&ex, 5);
        let r = ex.run_traced().unwrap();
        assert_eq!(r.trace.len(), 5);
        // Chain tasks are strictly ordered in time.
        for w in r.trace.windows(2) {
            assert!(w[1].start_s >= w[0].end_s - 1e-12);
        }
        // Untraced runs keep the trace empty.
        let ex2 = SimExecutor::new(SimConfig::with_workers(3));
        submit_chain(&ex2, 5);
        assert!(ex2.run().unwrap().trace.is_empty());
        // CSV export round-trips.
        let p = std::env::temp_dir().join(format!("sim_trace_{}.csv", std::process::id()));
        r.write_trace_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 6); // header + 5 tasks
        assert!(text.starts_with("task,worker,start_s,end_s"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn utilization_bounded() {
        let ex = SimExecutor::new(SimConfig::with_workers(4));
        submit_chain(&ex, 10);
        let r = ex.run().unwrap();
        assert!(r.utilization > 0.0 && r.utilization <= 1.0 + 1e-9);
    }
}
