//! Runtime metrics: per-op task counts and traffic.
//!
//! Task counts are first-class experimental quantities in the paper (the
//! `N²+N` vs `N` transpose claim, `N·min(N,S)+N` vs `2N` shuffle claim), so
//! the runtime counts them on every submission and the benches assert the
//! formulas (DESIGN.md §6, EXP-TASKS).

use std::collections::BTreeMap;

/// Snapshot of accumulated metrics. Cloneable plain data.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Tasks submitted, keyed by op name.
    pub tasks_by_op: BTreeMap<&'static str, u64>,
    /// Total input futures declared across tasks (collection reads count
    /// each element, matching how PyCOMPSs sees collection parameters).
    pub read_edges: u64,
    /// Total output futures produced by tasks.
    pub write_edges: u64,
    /// Total declared input bytes.
    pub read_bytes: f64,
    /// Total declared output bytes.
    pub write_bytes: f64,
    /// Bytes of block values currently resident in the executor's data
    /// table (local mode; sim mode never materializes values).
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` — the memory ceiling a pipeline
    /// actually needed, the headline number of refcount reclamation.
    pub peak_resident_bytes: u64,
    /// Blocks reclaimed by refcount eviction (fully consumed, unpinned),
    /// including blocks granted exclusively to in-place tasks.
    pub blocks_evicted: u64,
    /// Per-block task submissions avoided by expression fusion: a fused
    /// task covering k logical elementwise ops contributes k − 1.
    pub tasks_fused: u64,
    /// Input blocks handed exclusively to ownership-aware tasks at claim
    /// time (the fused closure mutates these buffers in place).
    pub inplace_hits: u64,
    /// Output bytes stored from fresh allocations — per task, stored output
    /// bytes minus exclusively-granted input bytes (floored at 0), so
    /// in-place execution shows up as bytes *not* allocated.
    pub bytes_allocated: u64,
    /// Live blocks pushed out of memory by the `memory_budget_bytes`
    /// resident-set policy (value moved to the spill store; the block stays
    /// referenced and faults back in on next use).
    pub blocks_spilled: u64,
    /// Spilled blocks read back into memory at task-input resolution or
    /// `wait` time.
    pub blocks_faulted: u64,
    /// Bytes actually written to spill files. Clean re-spills (the on-disk
    /// copy is still valid) drop the value without rewriting and add 0.
    pub spill_bytes: u64,
    /// Payload + frame bytes moved over TCP by the cluster backend:
    /// coordinator↔worker puts/gets and worker↔worker pulls.
    pub bytes_on_wire: u64,
    /// Task inputs that had to cross workers (pulled to the placement
    /// worker, or relayed from a non-placement holder).
    pub remote_transfers: u64,
    /// Task inputs already resident on the worker the task was placed on —
    /// the locality scheduler's payoff counter.
    pub locality_hits: u64,
    /// Block-level kernel dispatches that went to a SIMD table (process-
    /// global, folded into snapshots by `Runtime::metrics`).
    pub simd_kernel_hits: u64,
    /// Tasks avoided by the plan layer's common-subexpression elimination —
    /// memo hits return the memoized task set instead of resubmitting it
    /// (folded into snapshots by `Runtime::metrics`, like
    /// `simd_kernel_hits`).
    pub tasks_deduped: u64,
    /// Operand blocks released inside a plan's own scheduler critical
    /// section (dead-block pre-release at gemm force time), so the spill
    /// tier sees memory pressure later.
    pub blocks_prereleased: u64,
    /// Sub-range work items created by intra-block splitting — fat block
    /// tasks that fanned out over the per-worker deques instead of
    /// serializing one worker (counts every part of every engaged split).
    pub subtasks_spawned: u64,
    /// Worker processes whose TCP conversation broke mid-run (each counted
    /// once; the coordinator never talks to a lost worker again).
    pub workers_lost: u64,
    /// Blocks whose every replica died with lost workers and were made
    /// re-derivable again (by lineage replay or a root-store reload).
    pub blocks_recovered: u64,
    /// Completed tasks re-queued by lineage recovery to re-derive lost
    /// blocks on the surviving workers.
    pub tasks_replayed: u64,
    /// Total time spent in recovery handling (marking the loss, walking the
    /// lineage, re-arming the replay sub-graph), in milliseconds rounded up
    /// — each recovery event contributes at least 1.
    pub recovery_ms: u64,
    /// Workers enrolled into a running cluster after boot (via
    /// `Request::Join` on the coordinator's control listener).
    pub workers_joined: u64,
    /// Workers decommissioned gracefully: scheduling stopped, sole-copy
    /// blocks migrated to survivors, zero tasks replayed.
    pub workers_drained: u64,
    /// Straggler tasks speculatively re-armed on another worker (the
    /// re-arms, not the completions; first completion wins either way).
    pub tasks_speculated: u64,
    /// Tasks executed per cluster worker slot (indexed by worker bit
    /// position; grows when workers join). Local/sim backends leave this
    /// empty.
    pub tasks_by_worker: Vec<u64>,
    /// Predict requests the serving tier answered with a result
    /// (overlaid onto snapshots by `ServerHandle::metrics`).
    pub requests_served: u64,
    /// Serving batches that coalesced more than one concurrent request
    /// into a single block-sized task.
    pub batches_coalesced: u64,
    /// Predict requests shed by serving admission control with an explicit
    /// `Overloaded` response.
    pub requests_shed: u64,
    /// Log₂ serving-latency histogram: bucket `b` counts requests answered
    /// in `[2^b, 2^(b+1))` microseconds, enqueue to reply (see
    /// [`latency_bucket`]). Empty outside serving.
    pub predict_latency_us_hist: Vec<u64>,
}

/// Buckets in [`Metrics::predict_latency_us_hist`]: the last bucket absorbs
/// everything from `2^23` µs (~8.4 s) up.
pub const LATENCY_BUCKETS: usize = 24;

/// Histogram bucket for a request latency of `us` microseconds:
/// `floor(log2(us))`, clamped into `0..LATENCY_BUCKETS`.
pub fn latency_bucket(us: u64) -> usize {
    (63 - us.max(1).leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
}

impl Metrics {
    pub fn record_submit(
        &mut self,
        name: &'static str,
        reads: usize,
        writes: usize,
        read_bytes: f64,
        write_bytes: f64,
    ) {
        *self.tasks_by_op.entry(name).or_insert(0) += 1;
        self.read_edges += reads as u64;
        self.write_edges += writes as u64;
        self.read_bytes += read_bytes;
        self.write_bytes += write_bytes;
    }

    /// A block value became resident (put_block or task output stored).
    pub fn record_resident(&mut self, bytes: usize) {
        self.resident_bytes += bytes as u64;
        if self.resident_bytes > self.peak_resident_bytes {
            self.peak_resident_bytes = self.resident_bytes;
        }
    }

    /// A block value was reclaimed by refcount eviction.
    pub fn record_evicted(&mut self, bytes: usize) {
        self.resident_bytes = self.resident_bytes.saturating_sub(bytes as u64);
        self.blocks_evicted += 1;
    }

    /// A task fusing `ops` logical operations was submitted (ordinary tasks
    /// pass 1 and contribute nothing).
    pub fn record_fused(&mut self, ops: u32) {
        self.tasks_fused += u64::from(ops.saturating_sub(1));
    }

    /// An input block was granted exclusively to an in-place task.
    pub fn record_inplace_grant(&mut self, bytes: usize) {
        self.inplace_hits += 1;
        self.resident_bytes = self.resident_bytes.saturating_sub(bytes as u64);
        self.blocks_evicted += 1;
    }

    /// A completed task stored `stored` output bytes after receiving
    /// `granted` bytes of exclusively-owned inputs (reused in place).
    pub fn record_allocated(&mut self, stored: usize, granted: usize) {
        self.bytes_allocated += stored.saturating_sub(granted) as u64;
    }

    /// A live block of `resident` payload bytes was spilled to disk;
    /// `written` is what the spill actually wrote (0 for clean drops whose
    /// on-disk copy was still valid).
    pub fn record_spilled(&mut self, resident: usize, written: u64) {
        self.blocks_spilled += 1;
        self.spill_bytes += written;
        self.resident_bytes = self.resident_bytes.saturating_sub(resident as u64);
    }

    /// A spilled block was faulted back into memory.
    pub fn record_faulted(&mut self, bytes: usize) {
        self.blocks_faulted += 1;
        self.record_resident(bytes);
    }

    /// `bytes` moved over the cluster backend's TCP links.
    pub fn record_wire(&mut self, bytes: u64) {
        self.bytes_on_wire += bytes;
    }

    /// A task was placed: `hits` inputs were already on the placement
    /// worker, `transfers` had to cross workers to reach the closure.
    pub fn record_locality(&mut self, hits: u64, transfers: u64) {
        self.locality_hits += hits;
        self.remote_transfers += transfers;
    }

    /// A fat block task split into `parts` sub-range work items on the
    /// executor's deques.
    pub fn record_subtasks(&mut self, parts: u64) {
        self.subtasks_spawned += parts;
    }

    /// One worker's death was absorbed by lineage recovery: `blocks` lost
    /// their last replica and became re-derivable again, `tasks` completed
    /// tasks were re-queued for replay, and the handling took `ms`
    /// milliseconds (pre-rounded up to at least 1 by the caller).
    pub fn record_recovery(&mut self, blocks: u64, tasks: u64, ms: u64) {
        self.workers_lost += 1;
        self.blocks_recovered += blocks;
        self.tasks_replayed += tasks;
        self.recovery_ms += ms;
    }

    /// A worker was enrolled into the running fleet.
    pub fn record_join(&mut self) {
        self.workers_joined += 1;
    }

    /// A worker was decommissioned gracefully (drain, not death).
    pub fn record_drain(&mut self) {
        self.workers_drained += 1;
    }

    /// A running task was speculatively re-armed on another worker.
    pub fn record_speculated(&mut self) {
        self.tasks_speculated += 1;
    }

    /// A task ran with worker slot `w` as its placement (the slot vector
    /// grows on demand as workers join).
    pub fn record_task_on_worker(&mut self, w: usize) {
        if self.tasks_by_worker.len() <= w {
            self.tasks_by_worker.resize(w + 1, 0);
        }
        self.tasks_by_worker[w] += 1;
    }

    pub fn total_tasks(&self) -> u64 {
        self.tasks_by_op.values().sum()
    }

    pub fn tasks_for(&self, op: &str) -> u64 {
        self.tasks_by_op
            .iter()
            .filter(|(k, _)| **k == op)
            .map(|(_, v)| *v)
            .sum()
    }

    /// Tasks whose op name starts with `prefix` — ops are namespaced like
    /// `dsarray.transpose.block` so prefixes select whole operations.
    pub fn tasks_with_prefix(&self, prefix: &str) -> u64 {
        self.tasks_by_op
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| *v)
            .sum()
    }

    /// Difference vs an earlier snapshot (for measuring one operation).
    /// `resident_bytes`/`peak_resident_bytes` are point-in-time values and
    /// are carried over unchanged; `blocks_evicted` is differenced.
    pub fn since(&self, earlier: &Metrics) -> Metrics {
        let mut out = self.clone();
        for (k, v) in &earlier.tasks_by_op {
            if let Some(x) = out.tasks_by_op.get_mut(k) {
                *x -= v;
            }
        }
        out.tasks_by_op.retain(|_, v| *v > 0);
        out.read_edges -= earlier.read_edges;
        out.write_edges -= earlier.write_edges;
        out.read_bytes -= earlier.read_bytes;
        out.write_bytes -= earlier.write_bytes;
        out.blocks_evicted -= earlier.blocks_evicted;
        out.tasks_fused -= earlier.tasks_fused;
        out.inplace_hits -= earlier.inplace_hits;
        out.bytes_allocated -= earlier.bytes_allocated;
        out.blocks_spilled -= earlier.blocks_spilled;
        out.blocks_faulted -= earlier.blocks_faulted;
        out.spill_bytes -= earlier.spill_bytes;
        out.bytes_on_wire -= earlier.bytes_on_wire;
        out.remote_transfers -= earlier.remote_transfers;
        out.locality_hits -= earlier.locality_hits;
        out.simd_kernel_hits -= earlier.simd_kernel_hits;
        out.tasks_deduped -= earlier.tasks_deduped;
        out.blocks_prereleased -= earlier.blocks_prereleased;
        out.subtasks_spawned -= earlier.subtasks_spawned;
        out.workers_lost -= earlier.workers_lost;
        out.blocks_recovered -= earlier.blocks_recovered;
        out.tasks_replayed -= earlier.tasks_replayed;
        out.recovery_ms -= earlier.recovery_ms;
        out.workers_joined -= earlier.workers_joined;
        out.workers_drained -= earlier.workers_drained;
        out.tasks_speculated -= earlier.tasks_speculated;
        out.requests_served -= earlier.requests_served;
        out.batches_coalesced -= earlier.batches_coalesced;
        out.requests_shed -= earlier.requests_shed;
        for (i, v) in earlier.tasks_by_worker.iter().enumerate() {
            if let Some(x) = out.tasks_by_worker.get_mut(i) {
                *x = x.saturating_sub(*v);
            }
        }
        for (i, v) in earlier.predict_latency_us_hist.iter().enumerate() {
            if let Some(x) = out.predict_latency_us_hist.get_mut(i) {
                *x = x.saturating_sub(*v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_and_prefix_queries() {
        let mut m = Metrics::default();
        m.record_submit("dsarray.transpose.block", 1, 1, 100.0, 100.0);
        m.record_submit("dsarray.transpose.block", 1, 1, 100.0, 100.0);
        m.record_submit("dataset.transpose.split", 1, 4, 50.0, 50.0);
        assert_eq!(m.total_tasks(), 3);
        assert_eq!(m.tasks_for("dsarray.transpose.block"), 2);
        assert_eq!(m.tasks_with_prefix("dsarray.transpose"), 2);
        assert_eq!(m.tasks_with_prefix("dataset."), 1);
        assert_eq!(m.read_edges, 3);
        assert_eq!(m.write_edges, 6);
    }

    #[test]
    fn since_subtracts() {
        let mut m = Metrics::default();
        m.record_submit("a", 1, 1, 10.0, 10.0);
        let snap = m.clone();
        m.record_submit("a", 2, 1, 10.0, 10.0);
        m.record_submit("b", 1, 1, 5.0, 5.0);
        let d = m.since(&snap);
        assert_eq!(d.total_tasks(), 2);
        assert_eq!(d.tasks_for("a"), 1);
        assert_eq!(d.tasks_for("b"), 1);
        assert_eq!(d.read_edges, 3);
    }

    #[test]
    fn fusion_and_inplace_counters() {
        let mut m = Metrics::default();
        m.record_fused(1); // ordinary task: no credit
        m.record_fused(3); // fuses 3 ops: 2 submissions avoided
        assert_eq!(m.tasks_fused, 2);
        m.record_resident(100);
        m.record_inplace_grant(40);
        assert_eq!(m.inplace_hits, 1);
        assert_eq!(m.resident_bytes, 60);
        assert_eq!(m.blocks_evicted, 1);
        m.record_allocated(50, 40);
        m.record_allocated(10, 30); // full reuse floors at 0
        assert_eq!(m.bytes_allocated, 10);
        let snap = m.clone();
        m.record_fused(2);
        m.record_allocated(8, 0);
        let d = m.since(&snap);
        assert_eq!(d.tasks_fused, 1);
        assert_eq!(d.inplace_hits, 0);
        assert_eq!(d.bytes_allocated, 8);
    }

    #[test]
    fn spill_and_fault_counters() {
        let mut m = Metrics::default();
        m.record_resident(1000);
        m.record_spilled(400, 400); // dirty: written to disk
        assert_eq!(m.resident_bytes, 600);
        assert_eq!((m.blocks_spilled, m.spill_bytes), (1, 400));
        m.record_faulted(400);
        assert_eq!(m.resident_bytes, 1000);
        assert_eq!(m.blocks_faulted, 1);
        m.record_spilled(400, 0); // clean re-spill: nothing rewritten
        assert_eq!((m.blocks_spilled, m.spill_bytes), (2, 400));
        assert_eq!(m.resident_bytes, 600);
        assert_eq!(m.peak_resident_bytes, 1000);
        let snap = m.clone();
        m.record_spilled(100, 100);
        m.record_faulted(100);
        let d = m.since(&snap);
        assert_eq!((d.blocks_spilled, d.blocks_faulted, d.spill_bytes), (1, 1, 100));
    }

    #[test]
    fn kernel_and_subtask_counters() {
        let mut m = Metrics::default();
        m.record_subtasks(4);
        m.record_subtasks(8);
        m.simd_kernel_hits = 3;
        assert_eq!(m.subtasks_spawned, 12);
        let snap = m.clone();
        m.record_subtasks(2);
        m.simd_kernel_hits = 5;
        let d = m.since(&snap);
        assert_eq!(d.subtasks_spawned, 2);
        assert_eq!(d.simd_kernel_hits, 2);
    }

    #[test]
    fn wire_and_locality_counters() {
        let mut m = Metrics::default();
        m.record_wire(1000);
        m.record_locality(3, 1);
        m.record_wire(24);
        assert_eq!(m.bytes_on_wire, 1024);
        assert_eq!(m.locality_hits, 3);
        assert_eq!(m.remote_transfers, 1);
        let snap = m.clone();
        m.record_wire(6);
        m.record_locality(0, 2);
        let d = m.since(&snap);
        assert_eq!(d.bytes_on_wire, 6);
        assert_eq!(d.locality_hits, 0);
        assert_eq!(d.remote_transfers, 2);
    }

    #[test]
    fn recovery_counters() {
        let mut m = Metrics::default();
        m.record_recovery(5, 3, 2);
        m.record_recovery(0, 0, 1); // a death that lost no live blocks
        assert_eq!(m.workers_lost, 2);
        assert_eq!(m.blocks_recovered, 5);
        assert_eq!(m.tasks_replayed, 3);
        assert_eq!(m.recovery_ms, 3);
        let snap = m.clone();
        m.record_recovery(2, 2, 1);
        let d = m.since(&snap);
        assert_eq!(
            (d.workers_lost, d.blocks_recovered, d.tasks_replayed, d.recovery_ms),
            (1, 2, 2, 1)
        );
    }

    #[test]
    fn elasticity_counters() {
        let mut m = Metrics::default();
        m.record_join();
        m.record_drain();
        m.record_speculated();
        m.record_speculated();
        m.record_task_on_worker(0);
        m.record_task_on_worker(2); // slot vector grows on demand
        m.record_task_on_worker(2);
        assert_eq!(m.workers_joined, 1);
        assert_eq!(m.workers_drained, 1);
        assert_eq!(m.tasks_speculated, 2);
        assert_eq!(m.tasks_by_worker, vec![1, 0, 2]);
        let snap = m.clone();
        m.record_join();
        m.record_task_on_worker(1);
        m.record_task_on_worker(2);
        let d = m.since(&snap);
        assert_eq!(d.workers_joined, 1);
        assert_eq!(d.workers_drained, 0);
        assert_eq!(d.tasks_speculated, 0);
        assert_eq!(d.tasks_by_worker, vec![0, 1, 1]);
    }

    #[test]
    fn serving_counters_and_latency_buckets() {
        // Bucket b covers [2^b, 2^(b+1)) µs; extremes clamp into range.
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1), 0);
        assert_eq!(latency_bucket(2), 1);
        assert_eq!(latency_bucket(3), 1);
        assert_eq!(latency_bucket(1024), 10);
        assert_eq!(latency_bucket(u64::MAX), LATENCY_BUCKETS - 1);
        let mut m = Metrics {
            requests_served: 10,
            batches_coalesced: 2,
            requests_shed: 1,
            predict_latency_us_hist: vec![0; LATENCY_BUCKETS],
            ..Default::default()
        };
        m.predict_latency_us_hist[latency_bucket(700)] = 10;
        let snap = m.clone();
        m.requests_served += 5;
        m.batches_coalesced += 1;
        m.predict_latency_us_hist[latency_bucket(700)] += 5;
        let d = m.since(&snap);
        assert_eq!(d.requests_served, 5);
        assert_eq!(d.batches_coalesced, 1);
        assert_eq!(d.requests_shed, 0);
        assert_eq!(d.predict_latency_us_hist[9], 5);
    }

    #[test]
    fn residency_tracking_peaks_and_evicts() {
        let mut m = Metrics::default();
        m.record_resident(100);
        m.record_resident(50);
        assert_eq!(m.resident_bytes, 150);
        assert_eq!(m.peak_resident_bytes, 150);
        m.record_evicted(100);
        assert_eq!(m.resident_bytes, 50);
        assert_eq!(m.peak_resident_bytes, 150, "peak is a high-water mark");
        assert_eq!(m.blocks_evicted, 1);
        m.record_resident(20);
        assert_eq!(m.peak_resident_bytes, 150);
    }
}
