//! Real master–worker executor: a pool of OS threads executes tasks as they
//! become dependency-free, mirroring PyCOMPSs' asynchronous task scheduling
//! (paper §3.1.2). The submitting thread plays the master (graph insertion);
//! workers pull ready tasks, resolve input futures, run the task function
//! and publish outputs, waking dependents.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::storage::{Block, BlockMeta};

use super::graph::{Graph, TaskState};
use super::metrics::Metrics;
use super::task::{CostHint, DataId, TaskFn, TaskId};

struct State {
    graph: Graph,
    ready: VecDeque<TaskId>,
    running: usize,
    shutdown: bool,
    /// First task failure; poisons the runtime (fail-fast).
    error: Option<String>,
    metrics: Metrics,
}

struct Inner {
    state: Mutex<State>,
    cv: Condvar,
}

pub struct LocalExecutor {
    inner: Arc<Inner>,
    workers: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl LocalExecutor {
    pub fn new(workers: usize) -> Self {
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                graph: Graph::default(),
                ready: VecDeque::new(),
                running: 0,
                shutdown: false,
                error: None,
                metrics: Metrics::default(),
            }),
            cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        Self {
            inner,
            workers,
            handles: Mutex::new(handles),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn put_block(&self, block: Block) -> DataId {
        let mut st = self.inner.state.lock().unwrap();
        st.graph.put_block(block.meta(), Some(Arc::new(block)))
    }

    pub fn submit(
        &self,
        name: &'static str,
        reads: &[DataId],
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        read_bytes: f64,
        f: TaskFn,
    ) -> Vec<DataId> {
        let mut st = self.inner.state.lock().unwrap();
        let n_out = out_metas.len();
        let write_bytes: f64 = out_metas.iter().map(|m| m.bytes() as f64).sum();
        let (tid, outs, ready) = st.graph.submit(name, reads, out_metas, hint, read_bytes, f);
        st.metrics
            .record_submit(name, reads.len(), n_out, read_bytes, write_bytes);
        if ready {
            st.ready.push_back(tid);
            self.inner.cv.notify_one();
        }
        outs
    }

    pub fn wait(&self, id: DataId) -> Result<Arc<Block>> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            if let Some(v) = &st.graph.data[id as usize].value {
                return Ok(Arc::clone(v));
            }
            // Deadlock guard: nothing running, nothing ready, value absent.
            if st.running == 0 && st.ready.is_empty() {
                bail!("wait({id}) would deadlock: no runnable producer");
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    pub fn barrier(&self) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            if st.running == 0 && st.ready.is_empty() {
                // All pending tasks must be blocked forever (impossible in a
                // DAG unless the graph is malformed) — assert clean finish.
                let stuck = st
                    .graph
                    .tasks
                    .iter()
                    .filter(|t| t.state == TaskState::Pending)
                    .count();
                if stuck > 0 {
                    bail!("barrier: {stuck} tasks stuck pending (malformed graph)");
                }
                return Ok(());
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    pub fn metrics(&self) -> Metrics {
        self.inner.state.lock().unwrap().metrics.clone()
    }
}

impl Drop for LocalExecutor {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        // Claim a ready task.
        let (tid, func, inputs) = {
            let mut st = inner.state.lock().unwrap();
            let tid = loop {
                if st.shutdown {
                    return;
                }
                if let Some(t) = st.ready.pop_front() {
                    break t;
                }
                st = inner.cv.wait(st).unwrap();
            };
            st.graph.tasks[tid as usize].state = TaskState::Running;
            st.running += 1;
            let node = &st.graph.tasks[tid as usize];
            let func = Arc::clone(&node.spec.func);
            // Readiness guarantees every input value is resolved.
            let inputs: Vec<Arc<Block>> = node
                .spec
                .reads
                .iter()
                .map(|&r| {
                    st.graph.data[r as usize]
                        .value
                        .as_ref()
                        .map(Arc::clone)
                        .ok_or_else(|| anyhow!("input {r} unresolved for ready task"))
                })
                .collect::<Result<_>>()
                .unwrap_or_default();
            (tid, func, inputs)
        };

        // Run outside the lock.
        let result = func(&inputs);

        let mut st = inner.state.lock().unwrap();
        st.running -= 1;
        match result {
            Ok(outs) => {
                let expected = st.graph.tasks[tid as usize].spec.arity_out();
                if outs.len() != expected {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.error.get_or_insert(format!(
                        "task `{name}` returned {} outputs, declared {expected}",
                        outs.len()
                    ));
                } else {
                    let now_ready = st.graph.complete(tid, Some(outs));
                    for t in now_ready {
                        st.ready.push_back(t);
                    }
                }
            }
            Err(e) => {
                let name = st.graph.tasks[tid as usize].spec.name;
                st.graph.tasks[tid as usize].state = TaskState::Failed;
                st.error.get_or_insert(format!("task `{name}` failed: {e}"));
            }
        }
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;

    fn add_op(delta: f32) -> TaskFn {
        Arc::new(move |ins: &[Arc<Block>]| {
            let m = ins[0].as_dense()?;
            Ok(vec![Block::Dense(m.map(|x| x + delta))])
        })
    }

    #[test]
    fn wide_fanout_executes_fully() {
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let mut outs = Vec::new();
        for i in 0..64 {
            let o = ex.submit(
                "fan",
                &[src],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                16.0,
                add_op(i as f32),
            );
            outs.push(o[0]);
        }
        ex.barrier().unwrap();
        for (i, &o) in outs.iter().enumerate() {
            let v = ex.wait(o).unwrap();
            assert_eq!(v.as_dense().unwrap().get(0, 0), 1.0 + i as f32);
        }
        assert_eq!(ex.metrics().total_tasks(), 64);
    }

    #[test]
    fn deep_chain_is_ordered() {
        let ex = LocalExecutor::new(3);
        let mut cur = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        for _ in 0..100 {
            cur = ex.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(1, 1)],
                CostHint::default(),
                4.0,
                add_op(1.0),
            )[0];
        }
        let v = ex.wait(cur).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 100.0);
    }

    #[test]
    fn task_error_poisons_runtime() {
        let ex = LocalExecutor::new(2);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let bad = ex.submit(
            "explode",
            &[src],
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            4.0,
            Arc::new(|_| anyhow::bail!("boom")),
        );
        assert!(ex.wait(bad[0]).is_err());
        assert!(ex.barrier().is_err());
    }

    #[test]
    fn arity_mismatch_detected() {
        let ex = LocalExecutor::new(1);
        let out = ex.submit(
            "liar",
            &[],
            vec![BlockMeta::dense(1, 1), BlockMeta::dense(1, 1)],
            CostHint::default(),
            0.0,
            Arc::new(|_| Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])),
        );
        assert!(ex.wait(out[0]).is_err());
    }

    #[test]
    fn collection_style_many_inputs() {
        let ex = LocalExecutor::new(4);
        let parts: Vec<DataId> = (0..32)
            .map(|i| ex.put_block(Block::Dense(DenseMatrix::full(1, 1, i as f32))))
            .collect();
        let sum = ex.submit(
            "reduce_all",
            &parts,
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            128.0,
            Arc::new(|ins: &[Arc<Block>]| {
                let s: f32 = ins.iter().map(|b| b.as_dense().unwrap().get(0, 0)).sum();
                Ok(vec![Block::Dense(DenseMatrix::full(1, 1, s))])
            }),
        );
        let v = ex.wait(sum[0]).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), (0..32).sum::<i32>() as f32);
    }
}
