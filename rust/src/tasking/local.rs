//! Real master–worker executor: a pool of OS threads executes tasks as they
//! become dependency-free, mirroring PyCOMPSs' asynchronous task scheduling
//! (paper §3.1.2).
//!
//! Scheduling layout (post executor-trait refactor):
//!
//! * **Batched insertion** — `submit_batch` inserts a whole slice of
//!   [`TaskSubmit`]s into the dependency graph under ONE acquisition of the
//!   central lock, amortizing the master's per-task scheduling cost exactly
//!   the way the paper's collection parameters amortize PyCOMPSs' (§3.1.2,
//!   §5.2).
//! * **Per-worker deques with stealing** — ready tasks land in per-worker
//!   deques (round-robin on submission, own-queue-first on completion for
//!   locality). A worker pops its own deque from the front; when empty it
//!   steals from the *costliest* victim's back, using the tasks'
//!   [`TaskSpec::cost_score`] as the backlog estimate, so big tasks migrate
//!   before trivial ones.
//! * **Refcount reclamation** — the graph tracks, per data id, outstanding
//!   task reads and application handle references; fully-consumed unpinned
//!   blocks are evicted from the data table and accounted in
//!   [`Metrics::blocks_evicted`] / `peak_resident_bytes`.
//!
//! Lock discipline: the central mutex guards the graph + counters; each
//! deque has its own mutex. Pushers hold central→deque (in that order);
//! poppers take a deque lock alone, release it, then take the central lock.
//! No thread ever holds a deque lock while acquiring the central lock, so
//! the two levels cannot deadlock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::storage::{Block, BlockMeta};

use super::graph::{Graph, TaskState};
use super::metrics::Metrics;
use super::task::{CostHint, DataId, OwnedTaskFn, TaskBody, TaskFn, TaskId, TaskInput, TaskSubmit};
use super::Executor;

/// One worker's ready deque plus its aggregate cost score (the steal
/// heuristic's victim-selection key).
#[derive(Default)]
struct SubQueue {
    dq: VecDeque<(TaskId, f64)>,
    cost: f64,
}

struct Central {
    graph: Graph,
    /// Ready tasks sitting in deques, not yet claimed by a worker.
    queued: usize,
    running: usize,
    shutdown: bool,
    /// First task failure; poisons the runtime (fail-fast).
    error: Option<String>,
    metrics: Metrics,
}

struct Inner {
    state: Mutex<Central>,
    cv: Condvar,
    queues: Vec<Mutex<SubQueue>>,
    /// Round-robin pointer for distributing freshly-ready tasks.
    rr: AtomicUsize,
}

impl Inner {
    /// Push one ready task into worker `w`'s deque. Caller MUST hold the
    /// central lock (`st`) — that is what makes `queued` and the condvar
    /// wakeup race-free.
    fn push_ready(&self, st: &mut Central, w: usize, tid: TaskId, score: f64) {
        let mut q = self.queues[w].lock().unwrap();
        q.dq.push_back((tid, score));
        q.cost += score;
        st.queued += 1;
    }

    fn next_rr(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed) % self.queues.len()
    }
}

pub struct LocalExecutor {
    inner: Arc<Inner>,
    workers: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl LocalExecutor {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(Central {
                graph: Graph::default(),
                queued: 0,
                running: 0,
                shutdown: false,
                error: None,
                metrics: Metrics::default(),
            }),
            cv: Condvar::new(),
            queues: (0..workers).map(|_| Mutex::new(SubQueue::default())).collect(),
            rr: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|me| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(inner, me))
            })
            .collect();
        Self {
            inner,
            workers,
            handles: Mutex::new(handles),
        }
    }

    /// Single-task convenience wrapper used by unit tests; the library goes
    /// through [`Executor::submit_batch`].
    pub fn submit(
        &self,
        name: &'static str,
        reads: &[DataId],
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        read_bytes: f64,
        f: TaskFn,
    ) -> Vec<DataId> {
        self.submit_batch(vec![TaskSubmit {
            name,
            reads: reads.to_vec(),
            out_metas,
            hint,
            read_bytes,
            body: TaskBody::Shared(f),
            fused_ops: 1,
        }])
        .pop()
        .expect("one entry per task")
    }
}

impl Executor for LocalExecutor {
    fn workers(&self) -> usize {
        self.workers
    }

    fn put_block(&self, block: Block) -> DataId {
        let bytes = block.meta().bytes();
        let mut st = self.inner.state.lock().unwrap();
        let id = st.graph.put_block(block.meta(), Some(Arc::new(block)));
        st.metrics.record_resident(bytes);
        id
    }

    /// Insert a whole batch under one central-lock acquisition — the
    /// master-side amortization this refactor is about. Tasks within a
    /// batch may read outputs of earlier tasks in the same batch (ids are
    /// allocated in order).
    fn submit_batch(&self, tasks: Vec<TaskSubmit>) -> Vec<Vec<DataId>> {
        self.submit_batch_releasing(tasks, &[])
    }

    /// Batch insertion plus handle releases in the SAME critical section:
    /// the reads register before the handles drop (nothing evicts early),
    /// and no claim can observe the stale handles (in-place grants for the
    /// batch's own tasks are deterministic, not submission-order races).
    fn submit_batch_releasing(
        &self,
        tasks: Vec<TaskSubmit>,
        release: &[DataId],
    ) -> Vec<Vec<DataId>> {
        let mut outs_all = Vec::with_capacity(tasks.len());
        let mut any_ready = false;
        {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            for t in tasks {
                let (tid, outs, ready) = st.graph.submit_record(t, &mut st.metrics);
                if ready {
                    let score = st.graph.tasks[tid as usize].spec.cost_score();
                    let w = self.inner.next_rr();
                    self.inner.push_ready(st, w, tid, score);
                    any_ready = true;
                }
                outs_all.push(outs);
            }
            for &id in release {
                if let Some(bytes) = st.graph.release(id) {
                    st.metrics.record_evicted(bytes);
                }
            }
        }
        if any_ready {
            self.inner.cv.notify_all();
        }
        outs_all
    }

    fn wait(&self, id: DataId) -> Result<Arc<Block>> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            let d = &st.graph.data[id as usize];
            if let Some(v) = &d.value {
                return Ok(Arc::clone(v));
            }
            if d.evicted {
                bail!("wait({id}): block was reclaimed (all handles released); pin it to keep it resident");
            }
            // Deadlock guard: nothing running, nothing queued, value absent.
            if st.running == 0 && st.queued == 0 {
                bail!("wait({id}) would deadlock: no runnable producer");
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    fn barrier(&self) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            if st.running == 0 && st.queued == 0 {
                // All pending tasks must be blocked forever (impossible in a
                // DAG unless the graph is malformed) — assert clean finish.
                let stuck = st
                    .graph
                    .tasks
                    .iter()
                    .filter(|t| t.state == TaskState::Pending)
                    .count();
                if stuck > 0 {
                    bail!("barrier: {stuck} tasks stuck pending (malformed graph)");
                }
                return Ok(());
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    fn metrics(&self) -> Metrics {
        self.inner.state.lock().unwrap().metrics.clone()
    }

    fn retain(&self, ids: &[DataId]) {
        let mut st = self.inner.state.lock().unwrap();
        for &id in ids {
            st.graph.retain(id);
        }
    }

    fn release(&self, ids: &[DataId]) {
        let mut st = self.inner.state.lock().unwrap();
        for &id in ids {
            if let Some(bytes) = st.graph.release(id) {
                st.metrics.record_evicted(bytes);
            }
        }
    }

    fn pin(&self, id: DataId) {
        let mut st = self.inner.state.lock().unwrap();
        st.graph.data[id as usize].pinned = true;
    }
}

impl Drop for LocalExecutor {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Grab work: own deque front first, then steal from the victim with the
/// largest queued cost (back of its deque), then a full fallback scan.
fn pop_task(inner: &Inner, me: usize) -> Option<TaskId> {
    {
        let mut q = inner.queues[me].lock().unwrap();
        if let Some((tid, s)) = q.dq.pop_front() {
            q.cost -= s;
            return Some(tid);
        }
        q.cost = 0.0; // reset float drift whenever provably empty
    }
    let n = inner.queues.len();
    let mut best: Option<(usize, f64)> = None;
    for v in 0..n {
        if v == me {
            continue;
        }
        // try_lock: victim selection must never wait behind a busy peer.
        if let Ok(q) = inner.queues[v].try_lock() {
            if !q.dq.is_empty() && best.map_or(true, |(_, c)| q.cost > c) {
                best = Some((v, q.cost));
            }
        }
    }
    if let Some((v, _)) = best {
        let mut q = inner.queues[v].lock().unwrap();
        if let Some((tid, s)) = q.dq.pop_back() {
            q.cost -= s;
            return Some(tid);
        }
    }
    for v in 0..n {
        if v == me {
            continue;
        }
        let mut q = inner.queues[v].lock().unwrap();
        if let Some((tid, s)) = q.dq.pop_back() {
            q.cost -= s;
            return Some(tid);
        }
    }
    None
}

/// A claimed task's body with its resolved inputs, ready to run outside
/// the central lock.
enum Resolved {
    Shared(TaskFn, Vec<Arc<Block>>),
    Owned(OwnedTaskFn, Vec<TaskInput>),
}

fn worker_loop(inner: Arc<Inner>, me: usize) {
    loop {
        // ---- Acquire a ready task (deque fast path, then park) ----
        let tid = match pop_task(&inner, me) {
            Some(t) => t,
            None => {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.queued > 0 {
                        break; // work appeared somewhere: rescan the deques
                    }
                    // Timeout is a belt-and-braces rescan, not a correctness
                    // requirement: pushes update `queued` under this mutex.
                    let (g, _) = inner
                        .cv
                        .wait_timeout(st, Duration::from_millis(10))
                        .unwrap();
                    st = g;
                }
                continue;
            }
        };

        // ---- Claim: transition to Running and resolve inputs ----
        let claimed = {
            let mut guard = inner.state.lock().unwrap();
            let st = &mut *guard;
            st.queued = st.queued.saturating_sub(1);
            st.graph.tasks[tid as usize].state = TaskState::Running;
            st.running += 1;
            let body = st.graph.tasks[tid as usize].spec.body.clone();
            let mut granted_bytes = 0usize;
            // Readiness guarantees every input is resolved; a hole here
            // (e.g. a reclaimed input resubmitted by a stale handle) is a
            // real error and must poison the runtime, not silently run the
            // task with empty inputs.
            let resolved: Result<Resolved> = match body {
                // Shared bodies only read the graph: resolve by borrow, no
                // copy of the reads list in the critical section.
                TaskBody::Shared(f) => st.graph.tasks[tid as usize]
                    .spec
                    .reads
                    .iter()
                    .map(|&r| {
                        st.graph.data[r as usize]
                            .value
                            .as_ref()
                            .map(Arc::clone)
                            .ok_or_else(|| anyhow!("input {r} unresolved for ready task"))
                    })
                    .collect::<Result<Vec<_>>>()
                    .map(|ins| Resolved::Shared(f, ins)),
                // Owned bodies mutate the data table (`take_exclusive`), so
                // the reads list is copied out first to release the borrow.
                TaskBody::Owned(f) => {
                    let reads: Vec<DataId> = st.graph.tasks[tid as usize].spec.reads.to_vec();
                    reads
                        .iter()
                        .enumerate()
                        .map(|(idx, &r)| {
                            // In-place hook: the task's FIRST input — by
                            // convention the fused evaluator's working
                            // buffer — is handed over exclusively when this
                            // task is its sole remaining consumer (the
                            // eviction condition with this read
                            // outstanding). Later inputs are read-only in
                            // the evaluator, so granting them would only
                            // inflate the in-place metrics; dead ones are
                            // reclaimed at completion as usual.
                            if idx == 0 {
                                if let Some(v) = st.graph.take_exclusive(r) {
                                    let bytes = v.meta().bytes();
                                    granted_bytes += bytes;
                                    st.metrics.record_inplace_grant(bytes);
                                    return Ok(TaskInput::Owned(v));
                                }
                            }
                            st.graph.data[r as usize]
                                .value
                                .as_ref()
                                .map(Arc::clone)
                                .map(TaskInput::Shared)
                                .ok_or_else(|| anyhow!("input {r} unresolved for ready task"))
                        })
                        .collect::<Result<Vec<_>>>()
                        .map(|ins| Resolved::Owned(f, ins))
                }
            };
            match resolved {
                Ok(res) => Ok((res, granted_bytes)),
                Err(e) => {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.running -= 1;
                    st.error.get_or_insert(format!("task `{name}` failed: {e}"));
                    Err(())
                }
            }
        };
        let (resolved, granted_bytes) = match claimed {
            Ok(fi) => fi,
            Err(()) => {
                inner.cv.notify_all();
                continue;
            }
        };

        // ---- Run outside the lock ----
        let result = match resolved {
            Resolved::Shared(f, ins) => {
                let r = f(&ins);
                drop(ins);
                r
            }
            Resolved::Owned(f, ins) => f(ins),
        };

        // ---- Publish: store outputs, wake dependents, reclaim inputs ----
        {
            let mut st = inner.state.lock().unwrap();
            st.running -= 1;
            match result {
                Ok(outs) => {
                    let expected = st.graph.tasks[tid as usize].spec.arity_out();
                    if outs.len() != expected {
                        let name = st.graph.tasks[tid as usize].spec.name;
                        st.graph.tasks[tid as usize].state = TaskState::Failed;
                        st.error.get_or_insert(format!(
                            "task `{name}` returned {} outputs, declared {expected}",
                            outs.len()
                        ));
                    } else {
                        let done = st.graph.complete(tid, Some(outs));
                        st.metrics.record_resident(done.stored_bytes);
                        st.metrics.record_allocated(done.stored_bytes, granted_bytes);
                        for bytes in done.evicted {
                            st.metrics.record_evicted(bytes);
                        }
                        for (i, dep) in done.now_ready.into_iter().enumerate() {
                            let score = st.graph.tasks[dep as usize].spec.cost_score();
                            // First unblocked dependent stays local (its
                            // inputs are warm here); the rest round-robin.
                            let w = if i == 0 { me } else { inner.next_rr() };
                            inner.push_ready(&mut st, w, dep, score);
                        }
                    }
                }
                Err(e) => {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.error.get_or_insert(format!("task `{name}` failed: {e}"));
                }
            }
        }
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;

    fn add_op(delta: f32) -> TaskFn {
        Arc::new(move |ins: &[Arc<Block>]| {
            let m = ins[0].as_dense()?;
            Ok(vec![Block::Dense(m.map(|x| x + delta))])
        })
    }

    #[test]
    fn wide_fanout_executes_fully() {
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let mut outs = Vec::new();
        for i in 0..64 {
            let o = ex.submit(
                "fan",
                &[src],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                16.0,
                add_op(i as f32),
            );
            outs.push(o[0]);
        }
        ex.barrier().unwrap();
        for (i, &o) in outs.iter().enumerate() {
            let v = ex.wait(o).unwrap();
            assert_eq!(v.as_dense().unwrap().get(0, 0), 1.0 + i as f32);
        }
        assert_eq!(ex.metrics().total_tasks(), 64);
    }

    #[test]
    fn deep_chain_is_ordered() {
        let ex = LocalExecutor::new(3);
        let mut cur = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        for _ in 0..100 {
            cur = ex.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(1, 1)],
                CostHint::default(),
                4.0,
                add_op(1.0),
            )[0];
        }
        let v = ex.wait(cur).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 100.0);
    }

    #[test]
    fn task_error_poisons_runtime() {
        let ex = LocalExecutor::new(2);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let bad = ex.submit(
            "explode",
            &[src],
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            4.0,
            Arc::new(|_| anyhow::bail!("boom")),
        );
        assert!(ex.wait(bad[0]).is_err());
        assert!(ex.barrier().is_err());
    }

    #[test]
    fn arity_mismatch_detected() {
        let ex = LocalExecutor::new(1);
        let out = ex.submit(
            "liar",
            &[],
            vec![BlockMeta::dense(1, 1), BlockMeta::dense(1, 1)],
            CostHint::default(),
            0.0,
            Arc::new(|_| Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])),
        );
        assert!(ex.wait(out[0]).is_err());
    }

    #[test]
    fn collection_style_many_inputs() {
        let ex = LocalExecutor::new(4);
        let parts: Vec<DataId> = (0..32)
            .map(|i| ex.put_block(Block::Dense(DenseMatrix::full(1, 1, i as f32))))
            .collect();
        let sum = ex.submit(
            "reduce_all",
            &parts,
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            128.0,
            Arc::new(|ins: &[Arc<Block>]| {
                let s: f32 = ins.iter().map(|b| b.as_dense().unwrap().get(0, 0)).sum();
                Ok(vec![Block::Dense(DenseMatrix::full(1, 1, s))])
            }),
        );
        let v = ex.wait(sum[0]).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), (0..32).sum::<i32>() as f32);
    }

    #[test]
    fn batch_submit_one_lock_many_tasks() {
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let batch: Vec<TaskSubmit> = (0..128)
            .map(|i| TaskSubmit {
                name: "batched",
                reads: vec![src],
                out_metas: vec![BlockMeta::dense(1, 1)],
                hint: CostHint::default(),
                read_bytes: 4.0,
                body: TaskBody::Shared(add_op(i as f32)),
                fused_ops: 1,
            })
            .collect();
        let outs = ex.submit_batch(batch);
        assert_eq!(outs.len(), 128);
        ex.barrier().unwrap();
        for (i, o) in outs.iter().enumerate() {
            let v = ex.wait(o[0]).unwrap();
            assert_eq!(v.as_dense().unwrap().get(0, 0), i as f32);
        }
        assert_eq!(ex.metrics().total_tasks(), 128);
    }

    #[test]
    fn intra_batch_dependencies_resolve() {
        // Task 1 of the batch reads task 0's output: ids are allocated in
        // order, so this must wire a dependency, not race.
        let ex = LocalExecutor::new(2);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 1.0)));
        let first = TaskSubmit {
            name: "first",
            reads: vec![src],
            out_metas: vec![BlockMeta::dense(1, 1)],
            hint: CostHint::default(),
            read_bytes: 4.0,
            body: TaskBody::Shared(add_op(10.0)),
            fused_ops: 1,
        };
        // The output id of `first` is predictable: next data id after src+1.
        let first_out: DataId = src + 1;
        let second = TaskSubmit {
            name: "second",
            reads: vec![first_out],
            out_metas: vec![BlockMeta::dense(1, 1)],
            hint: CostHint::default(),
            read_bytes: 4.0,
            body: TaskBody::Shared(add_op(100.0)),
            fused_ops: 1,
        };
        let outs = ex.submit_batch(vec![first, second]);
        assert_eq!(outs[0][0], first_out);
        let v = ex.wait(outs[1][0]).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 111.0);
    }

    #[test]
    fn contention_stress_submitters_vs_waiters() {
        // Many threads submitting while others barrier/wait: the scheduler
        // must neither lose tasks nor deadlock (satellite: contention test).
        let ex = Arc::new(LocalExecutor::new(4));
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let n_threads = 6;
        let per_thread = 200;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let ex = Arc::clone(&ex);
            handles.push(std::thread::spawn(move || {
                let mut outs = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    let o = ex.submit(
                        "stress",
                        &[src],
                        vec![BlockMeta::dense(1, 1)],
                        CostHint::flops((i % 7) as f64 * 1e3),
                        4.0,
                        add_op((t * per_thread + i) as f32),
                    );
                    outs.push((o[0], (t * per_thread + i) as f32));
                    if i % 32 == 0 {
                        ex.barrier().unwrap();
                    }
                }
                for (id, want) in outs {
                    let v = ex.wait(id).unwrap();
                    assert_eq!(v.as_dense().unwrap().get(0, 0), want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        ex.barrier().unwrap();
        assert_eq!(
            ex.metrics().total_tasks(),
            (n_threads * per_thread) as u64
        );
    }

    #[test]
    fn owned_task_grants_inplace_only_for_dead_blocks() {
        use std::sync::atomic::AtomicBool;
        let ex = LocalExecutor::new(2);
        let kept = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let dead = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 2.0)));
        ex.retain(&[kept, dead]);
        // Gate the owned task behind a spinning predecessor so its claim —
        // where the grant decision happens — runs only after `dead`'s
        // handle is released.
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let gate_out = ex.submit(
            "gate",
            &[],
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            0.0,
            Arc::new(move |_ins: &[Arc<Block>]| {
                while !g.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
                Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])
            }),
        );
        // Ownership-aware task: adds 10 to every element of inputs it was
        // granted exclusively, so the grant decision is observable. `dead`
        // is the FIRST read — the only position eligible for a grant.
        let outs = ex.submit_batch(vec![TaskSubmit {
            name: "owned",
            reads: vec![dead, kept, gate_out[0]],
            out_metas: vec![
                BlockMeta::dense(2, 2),
                BlockMeta::dense(2, 2),
                BlockMeta::dense(1, 1),
            ],
            hint: CostHint::default(),
            read_bytes: 36.0,
            body: TaskBody::Owned(Arc::new(|ins: Vec<TaskInput>| {
                let mut outs = Vec::with_capacity(ins.len());
                for inp in ins {
                    let bump = if inp.is_owned() { 10.0 } else { 0.0 };
                    let mut d = inp.into_dense()?;
                    for x in d.data_mut() {
                        *x += bump;
                    }
                    outs.push(Block::Dense(d));
                }
                Ok(outs)
            })),
            fused_ops: 3,
        }]);
        // `dead`'s handle goes away while its reader is still pending: the
        // claim must hand the value over exclusively. `kept`'s handle stays.
        ex.release(&[dead]);
        gate.store(true, Ordering::SeqCst);
        ex.barrier().unwrap();
        let o = &outs[0];
        assert_eq!(ex.wait(o[0]).unwrap().as_dense().unwrap().get(0, 0), 12.0);
        assert_eq!(ex.wait(o[1]).unwrap().as_dense().unwrap().get(0, 0), 1.0);
        assert_eq!(ex.wait(o[2]).unwrap().as_dense().unwrap().get(0, 0), 0.0);
        // The granted block left the data table; the shared one survives.
        assert!(ex.wait(dead).is_err());
        assert!(ex.wait(kept).is_ok());
        let m = ex.metrics();
        assert_eq!(m.inplace_hits, 1);
        assert_eq!(m.tasks_fused, 2);
        // gate stored 4 B fresh; owned stored 36 B with 16 B reused.
        assert_eq!(m.bytes_allocated, 24);
    }

    #[test]
    fn stealing_drains_unbalanced_queues() {
        // One giant batch lands round-robin; with 4 workers and heavily
        // skewed costs every task must still execute exactly once.
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let batch: Vec<TaskSubmit> = (0..256)
            .map(|i| TaskSubmit {
                name: "skewed",
                reads: vec![src],
                out_metas: vec![BlockMeta::dense(1, 1)],
                hint: CostHint::flops(if i % 16 == 0 { 1e9 } else { 1.0 }),
                read_bytes: 4.0,
                body: TaskBody::Shared(add_op(1.0)),
                fused_ops: 1,
            })
            .collect();
        ex.submit_batch(batch);
        ex.barrier().unwrap();
        assert_eq!(ex.metrics().tasks_for("skewed"), 256);
    }
}
