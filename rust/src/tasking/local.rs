//! Real master–worker executor: a pool of OS threads executes tasks as they
//! become dependency-free, mirroring PyCOMPSs' asynchronous task scheduling
//! (paper §3.1.2).
//!
//! Scheduling layout (post executor-trait refactor):
//!
//! * **Batched insertion** — `submit_batch` inserts a whole slice of
//!   [`TaskSubmit`]s into the dependency graph under ONE acquisition of the
//!   central lock, amortizing the master's per-task scheduling cost exactly
//!   the way the paper's collection parameters amortize PyCOMPSs' (§3.1.2,
//!   §5.2).
//! * **Per-worker deques with stealing** — ready tasks land in per-worker
//!   deques (round-robin on submission, own-queue-first on completion for
//!   locality). A worker pops its own deque from the front; when empty it
//!   steals from the *costliest* victim's back, using the tasks'
//!   [`TaskSpec::cost_score`] as the backlog estimate, so big tasks migrate
//!   before trivial ones.
//! * **Refcount reclamation** — the graph tracks, per data id, outstanding
//!   task reads and application handle references; fully-consumed unpinned
//!   blocks are evicted from the data table and accounted in
//!   [`Metrics::blocks_evicted`] / `peak_resident_bytes`.
//! * **Intra-block sub-tasks** — a fat block task (big gemm tile grid,
//!   long fused chain) splits itself through the kernel layer's
//!   [`IntraPool`] hook: helper tokens land at the *front* of sibling
//!   deques and idle workers execute disjoint sub-ranges of the same block
//!   while the originator works through the rest. The split plan is
//!   size-gated and worker-count independent, so results stay bit-identical
//!   (see `kernels`); accounted in [`Metrics::subtasks_spawned`].
//! * **Out-of-core residency** — with a [`LocalOptions`] memory budget,
//!   *live* blocks past the high-water mark are spilled LRU-first to a
//!   per-runtime [`BlockStore`] directory (write-back for dirty values,
//!   free drop for clean ones) and faulted back at task-input resolution
//!   or `wait`; dead spilled blocks have their files unlinked eagerly.
//!   Spill/fault runs under the central lock: the policy is race-free
//!   because claiming workers hold `Arc` clones of their inputs.
//!
//! Lock discipline: the central mutex guards the graph + counters; each
//! deque has its own mutex. Pushers hold central→deque (in that order);
//! poppers take a deque lock alone, release it, then take the central lock.
//! No thread ever holds a deque lock while acquiring the central lock, so
//! the two levels cannot deadlock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::kernels::IntraPool;
use crate::storage::{Block, BlockMeta, BlockStore};

use super::graph::{Graph, TaskState};
use super::metrics::Metrics;
use super::task::{CostHint, DataId, OwnedTaskFn, TaskBody, TaskFn, TaskId, TaskInput, TaskSubmit};
use super::Executor;

/// One entry of a worker deque: either a whole ready task or a helper
/// token for an intra-block split in progress on a sibling worker.
enum WorkItem {
    /// Ready task and its cost score (the steal heuristic's unit).
    Task(TaskId, f64),
    /// Helper token: claim sub-ranges of a splitting task. Tokens carry no
    /// cost (the owning task's score already counts) and are pushed to the
    /// deque *front* — finishing an in-flight block beats starting new ones.
    Sub(Arc<SubTask>),
}

/// One worker's ready deque plus its aggregate cost score (the steal
/// heuristic's victim-selection key).
#[derive(Default)]
struct SubQueue {
    dq: VecDeque<WorkItem>,
    cost: f64,
}

/// A splitting task's shared claim state — the scoped-task pattern. `run`
/// borrows the originating task's stack; that borrow stays valid because
/// the originator blocks in [`DequePool::run`] until `done == parts`, and
/// after that point every `next.fetch_add` claim lands `>= parts` and
/// returns without touching `run`. Stale tokens left in deques after the
/// originator returns are therefore harmless no-ops.
struct SubTask {
    run: *const (dyn Fn(usize) + Sync),
    parts: usize,
    /// Next unclaimed part index; claims past `parts` are discards.
    next: AtomicUsize,
    /// Completed parts; the originator's wakeup condition.
    done: Mutex<usize>,
    cv: Condvar,
}

// SAFETY: `run` is dereferenced only for claims `< parts`, all of which
// complete before the originator (who owns the pointee) returns.
unsafe impl Send for SubTask {}
unsafe impl Sync for SubTask {}

impl SubTask {
    /// Claim and execute parts until none remain unclaimed.
    fn help(&self) {
        loop {
            let p = self.next.fetch_add(1, Ordering::Relaxed);
            if p >= self.parts {
                return;
            }
            // SAFETY: a claim below `parts` means the originator has not
            // returned yet, so the closure is alive (see struct docs).
            let f = unsafe { &*self.run };
            f(p);
            let mut d = self.done.lock().unwrap();
            *d += 1;
            if *d == self.parts {
                self.cv.notify_all();
            }
        }
    }
}

/// The local executor's [`IntraPool`]: sub-range work items go onto the
/// existing per-worker deques so idle siblings help with a fat block. One
/// instance per worker thread, installed at the top of its loop.
struct DequePool {
    inner: Weak<Inner>,
    me: usize,
}

impl IntraPool for DequePool {
    fn run(&self, parts: usize, f: &(dyn Fn(usize) + Sync)) -> bool {
        let Some(inner) = self.inner.upgrade() else {
            return false;
        };
        let n = inner.queues.len();
        if n <= 1 || parts <= 1 {
            return false; // nobody to help: caller runs inline
        }
        let sub = Arc::new(SubTask {
            run: f as *const (dyn Fn(usize) + Sync),
            parts,
            next: AtomicUsize::new(0),
            done: Mutex::new(0),
            cv: Condvar::new(),
        });
        // Offer at most one token per sibling, under the central lock
        // (lock order central→deque, same as push_ready).
        {
            let mut st = inner.state.lock().unwrap();
            st.metrics.record_subtasks(parts as u64);
            let tokens = (parts - 1).min(n - 1);
            for t in 0..tokens {
                let w = (self.me + 1 + t) % n;
                let mut q = inner.queues[w].lock().unwrap();
                q.dq.push_front(WorkItem::Sub(Arc::clone(&sub)));
            }
            st.subs += tokens;
        }
        inner.cv.notify_all();
        // The originator never idles: it claims parts alongside helpers.
        sub.help();
        // All parts are claimed; wait out the ones helpers still run.
        let mut d = sub.done.lock().unwrap();
        while *d < parts {
            d = sub.cv.wait(d).unwrap();
        }
        true
    }
}

/// Configuration of a [`LocalExecutor`] beyond the worker count — the
/// out-of-core memory budget and its spill directory.
#[derive(Clone, Debug, Default)]
pub struct LocalOptions {
    /// Worker threads (0 is clamped to 1).
    pub workers: usize,
    /// Resident-set high-water mark in bytes. When the payload bytes held
    /// in the data table exceed this, least-recently-used clean blocks are
    /// dropped and dirty ones written back to the spill store; spilled
    /// blocks fault back in transparently at task-input resolution or
    /// `wait`. `None` (the default) keeps everything resident.
    pub memory_budget_bytes: Option<u64>,
    /// Parent directory for spill files; defaults to the system temp dir.
    /// A uniquely-named per-runtime subdirectory is created under it (so
    /// runtimes sharing a parent never collide) and only that subdirectory
    /// is removed at teardown — never the parent itself.
    pub spill_dir: Option<std::path::PathBuf>,
}

impl LocalOptions {
    #[deprecated(
        since = "0.11.0",
        note = "use `Runtime::builder().workers(n)` or a struct literal with `..Default::default()`"
    )]
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            ..Self::default()
        }
    }

    pub fn with_memory_budget(mut self, bytes: u64) -> Self {
        self.memory_budget_bytes = Some(bytes);
        self
    }

    pub fn with_spill_dir(mut self, dir: std::path::PathBuf) -> Self {
        self.spill_dir = Some(dir);
        self
    }
}

struct Central {
    graph: Graph,
    /// Ready tasks sitting in deques, not yet claimed by a worker.
    queued: usize,
    /// Outstanding intra-block helper tokens in deques (wake condition for
    /// parked workers; tokens don't count as `queued` — their originating
    /// task is already `running`, which keeps the deadlock guards exact).
    subs: usize,
    running: usize,
    shutdown: bool,
    /// First task failure; poisons the runtime (fail-fast).
    error: Option<String>,
    metrics: Metrics,
    /// Resident-set high-water mark; `None` disables spilling.
    budget: Option<u64>,
    /// Spill backend; `Some` exactly when `budget` is set. Dropping it at
    /// executor teardown removes the spill directory.
    store: Option<BlockStore>,
}

/// Enforce the resident-set budget: spill least-recently-used blocks until
/// `resident_bytes` is back under the high-water mark. Clean blocks (valid
/// on-disk copy) are dropped for free; dirty ones are written back first.
/// Runs under the central lock — spilling is a stop-the-scheduler event,
/// which keeps the policy race-free (workers hold `Arc` clones of any
/// value they are actively computing on, so dropping the table reference
/// is always safe).
fn maybe_spill(st: &mut Central) {
    let Some(budget) = st.budget else { return };
    if st.metrics.resident_bytes <= budget {
        return;
    }
    let mut cands = st.graph.spill_candidates();
    cands.sort_unstable();
    for (_, id, bytes) in cands {
        if st.metrics.resident_bytes <= budget {
            break;
        }
        let d = &st.graph.data[id as usize];
        let (on_disk, value) = (d.on_disk, d.value.clone());
        let Some(v) = value else { continue };
        let mut written = 0u64;
        if !on_disk {
            let store = st.store.as_ref().expect("budget set implies store");
            match store.spill(id, &v) {
                Ok(w) => written = w,
                Err(e) => {
                    st.error.get_or_insert(format!("spill of block {id} failed: {e}"));
                    return;
                }
            }
        }
        let d = &mut st.graph.data[id as usize];
        d.value = None;
        d.on_disk = true;
        d.spilled = true;
        st.metrics.record_spilled(bytes, written);
    }
}

/// Fault one spilled block back into the data table (no-op when resident).
fn fault_in(st: &mut Central, id: DataId) -> Result<()> {
    let d = &st.graph.data[id as usize];
    if d.value.is_some() || !d.spilled {
        return Ok(());
    }
    let store = st.store.as_ref().expect("spilled block implies store");
    let block = store.fault(id)?;
    let bytes = block.meta().bytes();
    let d = &mut st.graph.data[id as usize];
    d.value = Some(Arc::new(block));
    d.spilled = false; // `on_disk` stays set: the copy is clean
    st.graph.touch(id);
    st.metrics.record_faulted(bytes);
    Ok(())
}

/// Unlink spill files of blocks that died (queued by the graph, which has
/// no file-system access of its own).
fn drain_dead_files(st: &mut Central) {
    if st.graph.dead_files.is_empty() {
        return;
    }
    let dead = std::mem::take(&mut st.graph.dead_files);
    if let Some(store) = &st.store {
        for id in dead {
            store.remove(id);
        }
    }
}

struct Inner {
    state: Mutex<Central>,
    cv: Condvar,
    queues: Vec<Mutex<SubQueue>>,
    /// Round-robin pointer for distributing freshly-ready tasks.
    rr: AtomicUsize,
}

impl Inner {
    /// Push one ready task into worker `w`'s deque. Caller MUST hold the
    /// central lock (`st`) — that is what makes `queued` and the condvar
    /// wakeup race-free.
    fn push_ready(&self, st: &mut Central, w: usize, tid: TaskId, score: f64) {
        let mut q = self.queues[w].lock().unwrap();
        q.dq.push_back(WorkItem::Task(tid, score));
        q.cost += score;
        st.queued += 1;
    }

    fn next_rr(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed) % self.queues.len()
    }
}

pub struct LocalExecutor {
    inner: Arc<Inner>,
    workers: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl LocalExecutor {
    pub fn new(workers: usize) -> Self {
        // Infallible: without a budget no spill directory is created.
        Self::with_options(LocalOptions {
            workers,
            ..Default::default()
        })
        .expect("budget-less executor needs no I/O")
    }

    /// Executor with an out-of-core memory budget (see [`LocalOptions`]).
    /// Errors if the spill directory cannot be created.
    pub fn with_options(opts: LocalOptions) -> Result<Self> {
        let workers = opts.workers.max(1);
        let store = match (&opts.memory_budget_bytes, &opts.spill_dir) {
            (Some(_), Some(parent)) => Some(BlockStore::new_unique_under(parent)?),
            (Some(_), None) => Some(BlockStore::in_temp()?),
            (None, _) => None,
        };
        let inner = Arc::new(Inner {
            state: Mutex::new(Central {
                graph: Graph::default(),
                queued: 0,
                subs: 0,
                running: 0,
                shutdown: false,
                error: None,
                metrics: Metrics::default(),
                budget: opts.memory_budget_bytes,
                store,
            }),
            cv: Condvar::new(),
            queues: (0..workers).map(|_| Mutex::new(SubQueue::default())).collect(),
            rr: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|me| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(inner, me))
            })
            .collect();
        Ok(Self {
            inner,
            workers,
            handles: Mutex::new(handles),
        })
    }

    /// Single-task convenience wrapper used by unit tests; the library goes
    /// through [`Executor::submit_batch`].
    pub fn submit(
        &self,
        name: &'static str,
        reads: &[DataId],
        out_metas: Vec<BlockMeta>,
        hint: CostHint,
        read_bytes: f64,
        f: TaskFn,
    ) -> Vec<DataId> {
        self.submit_batch(vec![TaskSubmit {
            name,
            reads: reads.to_vec(),
            out_metas,
            hint,
            read_bytes,
            body: TaskBody::Shared(f),
            fused_ops: 1,
        }])
        .pop()
        .expect("one entry per task")
    }
}

impl Executor for LocalExecutor {
    fn workers(&self) -> usize {
        self.workers
    }

    fn put_block(&self, block: Block) -> DataId {
        let bytes = block.meta().bytes();
        let mut st = self.inner.state.lock().unwrap();
        let id = st.graph.put_block(block.meta(), Some(Arc::new(block)));
        st.metrics.record_resident(bytes);
        // Streaming registration (e.g. `from_matrix` over a huge source)
        // spills older blocks as the budget fills — the data table never
        // holds more than budget + one block.
        maybe_spill(&mut st);
        id
    }

    /// Insert a whole batch under one central-lock acquisition — the
    /// master-side amortization this refactor is about. Tasks within a
    /// batch may read outputs of earlier tasks in the same batch (ids are
    /// allocated in order).
    fn submit_batch(&self, tasks: Vec<TaskSubmit>) -> Vec<Vec<DataId>> {
        self.submit_batch_releasing(tasks, &[])
    }

    /// Batch insertion plus handle releases in the SAME critical section:
    /// the reads register before the handles drop (nothing evicts early),
    /// and no claim can observe the stale handles (in-place grants for the
    /// batch's own tasks are deterministic, not submission-order races).
    fn submit_batch_releasing(
        &self,
        tasks: Vec<TaskSubmit>,
        release: &[DataId],
    ) -> Vec<Vec<DataId>> {
        let mut outs_all = Vec::with_capacity(tasks.len());
        let mut any_ready = false;
        {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            for t in tasks {
                let (tid, outs, ready) = st.graph.submit_record(t, &mut st.metrics);
                if ready {
                    let score = st.graph.tasks[tid as usize].spec.cost_score();
                    let w = self.inner.next_rr();
                    self.inner.push_ready(st, w, tid, score);
                    any_ready = true;
                }
                outs_all.push(outs);
            }
            for &id in release {
                if let Some(bytes) = st.graph.release(id) {
                    st.metrics.record_evicted(bytes);
                }
            }
            drain_dead_files(st);
        }
        if any_ready {
            self.inner.cv.notify_all();
        }
        outs_all
    }

    fn wait(&self, id: DataId) -> Result<Arc<Block>> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            let d = &st.graph.data[id as usize];
            if let Some(v) = &d.value {
                let v = Arc::clone(v);
                st.graph.touch(id);
                return Ok(v);
            }
            if d.spilled {
                // Transparent fault-in: synchronizing a spilled block reads
                // it back (and may push something else out).
                fault_in(&mut st, id)?;
                let v = st.graph.data[id as usize]
                    .value
                    .as_ref()
                    .map(Arc::clone)
                    .expect("fault_in installs the value");
                maybe_spill(&mut st);
                return Ok(v);
            }
            if d.evicted {
                bail!("wait({id}): block was reclaimed (all handles released); pin it to keep it resident");
            }
            // Deadlock guard: nothing running, nothing queued, value absent.
            if st.running == 0 && st.queued == 0 {
                bail!("wait({id}) would deadlock: no runnable producer");
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    fn barrier(&self) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("runtime poisoned by task failure: {err}");
            }
            if st.running == 0 && st.queued == 0 {
                // All pending tasks must be blocked forever (impossible in a
                // DAG unless the graph is malformed) — assert clean finish.
                let stuck = st
                    .graph
                    .tasks
                    .iter()
                    .filter(|t| t.state == TaskState::Pending)
                    .count();
                if stuck > 0 {
                    bail!("barrier: {stuck} tasks stuck pending (malformed graph)");
                }
                return Ok(());
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    fn metrics(&self) -> Metrics {
        self.inner.state.lock().unwrap().metrics.clone()
    }

    fn retain(&self, ids: &[DataId]) {
        let mut st = self.inner.state.lock().unwrap();
        for &id in ids {
            st.graph.retain(id);
        }
    }

    fn release(&self, ids: &[DataId]) {
        let mut st = self.inner.state.lock().unwrap();
        for &id in ids {
            if let Some(bytes) = st.graph.release(id) {
                st.metrics.record_evicted(bytes);
            }
        }
        drain_dead_files(&mut st);
    }

    fn pin(&self, id: DataId) {
        let mut st = self.inner.state.lock().unwrap();
        st.graph.data[id as usize].pinned = true;
    }
}

impl Drop for LocalExecutor {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Pop one item off a deque, maintaining the cost aggregate (helper tokens
/// carry no cost of their own).
fn take(q: &mut SubQueue, front: bool) -> Option<WorkItem> {
    let item = if front { q.dq.pop_front() } else { q.dq.pop_back() };
    if let Some(WorkItem::Task(_, s)) = &item {
        q.cost -= s;
    }
    item
}

/// Grab work: own deque front first, then steal from the victim with the
/// largest queued cost (back of its deque), then a full fallback scan.
fn pop_task(inner: &Inner, me: usize) -> Option<WorkItem> {
    {
        let mut q = inner.queues[me].lock().unwrap();
        if let Some(item) = take(&mut q, true) {
            return Some(item);
        }
        q.cost = 0.0; // reset float drift whenever provably empty
    }
    let n = inner.queues.len();
    let mut best: Option<(usize, f64)> = None;
    for v in 0..n {
        if v == me {
            continue;
        }
        // try_lock: victim selection must never wait behind a busy peer.
        if let Ok(q) = inner.queues[v].try_lock() {
            if !q.dq.is_empty() && best.map_or(true, |(_, c)| q.cost > c) {
                best = Some((v, q.cost));
            }
        }
    }
    if let Some((v, _)) = best {
        let mut q = inner.queues[v].lock().unwrap();
        if let Some(item) = take(&mut q, false) {
            return Some(item);
        }
    }
    for v in 0..n {
        if v == me {
            continue;
        }
        let mut q = inner.queues[v].lock().unwrap();
        if let Some(item) = take(&mut q, false) {
            return Some(item);
        }
    }
    None
}

/// A claimed task's body with its resolved inputs, ready to run outside
/// the central lock.
enum Resolved {
    Shared(TaskFn, Vec<Arc<Block>>),
    Owned(OwnedTaskFn, Vec<TaskInput>),
}

fn worker_loop(inner: Arc<Inner>, me: usize) {
    // Kernel-layer hook: block tasks running on this thread may split into
    // sub-ranges that land on sibling deques. Weak: the pool must not keep
    // the executor alive past its Drop.
    crate::kernels::install_pool(Some(Arc::new(DequePool {
        inner: Arc::downgrade(&inner),
        me,
    })));
    loop {
        // ---- Acquire a ready task (deque fast path, then park) ----
        let item = match pop_task(&inner, me) {
            Some(t) => t,
            None => {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.queued > 0 || st.subs > 0 {
                        break; // work appeared somewhere: rescan the deques
                    }
                    // Timeout is a belt-and-braces rescan, not a correctness
                    // requirement: pushes update `queued` under this mutex.
                    let (g, _) = inner
                        .cv
                        .wait_timeout(st, Duration::from_millis(10))
                        .unwrap();
                    st = g;
                }
                continue;
            }
        };
        let tid = match item {
            WorkItem::Task(tid, _) => tid,
            WorkItem::Sub(sub) => {
                // Helper token: work through the splitting task's remaining
                // sub-ranges, then go back to normal scheduling. Tokens
                // whose split already finished discard instantly.
                {
                    let mut st = inner.state.lock().unwrap();
                    st.subs = st.subs.saturating_sub(1);
                }
                sub.help();
                continue;
            }
        };

        // ---- Claim: transition to Running and resolve inputs ----
        let claimed = {
            let mut guard = inner.state.lock().unwrap();
            let st = &mut *guard;
            st.queued = st.queued.saturating_sub(1);
            st.graph.tasks[tid as usize].state = TaskState::Running;
            st.running += 1;
            let body = st.graph.tasks[tid as usize].spec.body.clone();
            let mut granted_bytes = 0usize;
            // Out-of-core: fault spilled inputs back in before resolution
            // and bump every input's LRU stamp so the task's working set is
            // the last thing the budget policy would push out.
            let faulted: Result<()> = {
                let reads: Vec<DataId> = st.graph.tasks[tid as usize].spec.reads.to_vec();
                reads.iter().try_for_each(|&r| {
                    fault_in(st, r)?;
                    st.graph.touch(r);
                    Ok(())
                })
            };
            // Readiness guarantees every input is resolved; a hole here
            // (e.g. a reclaimed input resubmitted by a stale handle) is a
            // real error and must poison the runtime, not silently run the
            // task with empty inputs.
            let resolved: Result<Resolved> = faulted.and_then(|()| match body {
                // Shared bodies only read the graph: resolve by borrow, no
                // copy of the reads list in the critical section.
                TaskBody::Shared(f) => st.graph.tasks[tid as usize]
                    .spec
                    .reads
                    .iter()
                    .map(|&r| {
                        st.graph.data[r as usize]
                            .value
                            .as_ref()
                            .map(Arc::clone)
                            .ok_or_else(|| anyhow!("input {r} unresolved for ready task"))
                    })
                    .collect::<Result<Vec<_>>>()
                    .map(|ins| Resolved::Shared(f, ins)),
                // Owned bodies mutate the data table (`take_exclusive`), so
                // the reads list is copied out first to release the borrow.
                TaskBody::Owned(f) => {
                    let reads: Vec<DataId> = st.graph.tasks[tid as usize].spec.reads.to_vec();
                    reads
                        .iter()
                        .enumerate()
                        .map(|(idx, &r)| {
                            // In-place hook: the task's FIRST input — by
                            // convention the fused evaluator's working
                            // buffer — is handed over exclusively when this
                            // task is its sole remaining consumer (the
                            // eviction condition with this read
                            // outstanding). Later inputs are read-only in
                            // the evaluator, so granting them would only
                            // inflate the in-place metrics; dead ones are
                            // reclaimed at completion as usual.
                            if idx == 0 {
                                if let Some(v) = st.graph.take_exclusive(r) {
                                    let bytes = v.meta().bytes();
                                    granted_bytes += bytes;
                                    st.metrics.record_inplace_grant(bytes);
                                    return Ok(TaskInput::Owned(v));
                                }
                            }
                            st.graph.data[r as usize]
                                .value
                                .as_ref()
                                .map(Arc::clone)
                                .map(TaskInput::Shared)
                                .ok_or_else(|| anyhow!("input {r} unresolved for ready task"))
                        })
                        .collect::<Result<Vec<_>>>()
                        .map(|ins| Resolved::Owned(f, ins))
                }
            });
            // Faulting may have pushed the resident set over budget; the
            // resolved inputs are Arc-cloned above, so re-spilling them is
            // safe (accounting only) and the task still runs on its values.
            drain_dead_files(st);
            maybe_spill(st);
            match resolved {
                Ok(res) => Ok((res, granted_bytes)),
                Err(e) => {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.running -= 1;
                    st.error.get_or_insert(format!("task `{name}` failed: {e}"));
                    Err(())
                }
            }
        };
        let (resolved, granted_bytes) = match claimed {
            Ok(fi) => fi,
            Err(()) => {
                inner.cv.notify_all();
                continue;
            }
        };

        // ---- Run outside the lock ----
        let result = match resolved {
            Resolved::Shared(f, ins) => {
                let r = f(&ins);
                drop(ins);
                r
            }
            Resolved::Owned(f, ins) => f(ins),
        };

        // ---- Publish: store outputs, wake dependents, reclaim inputs ----
        {
            let mut st = inner.state.lock().unwrap();
            st.running -= 1;
            match result {
                Ok(outs) => {
                    let expected = st.graph.tasks[tid as usize].spec.arity_out();
                    if outs.len() != expected {
                        let name = st.graph.tasks[tid as usize].spec.name;
                        st.graph.tasks[tid as usize].state = TaskState::Failed;
                        st.error.get_or_insert(format!(
                            "task `{name}` returned {} outputs, declared {expected}",
                            outs.len()
                        ));
                    } else {
                        let done = st.graph.complete(tid, Some(outs));
                        st.metrics.record_resident(done.stored_bytes);
                        st.metrics.record_allocated(done.stored_bytes, granted_bytes);
                        for bytes in done.evicted {
                            st.metrics.record_evicted(bytes);
                        }
                        // Fresh outputs may exceed the budget: unlink files
                        // of blocks this completion killed, then spill LRU
                        // blocks down to the high-water mark.
                        drain_dead_files(&mut st);
                        maybe_spill(&mut st);
                        for (i, dep) in done.now_ready.into_iter().enumerate() {
                            let score = st.graph.tasks[dep as usize].spec.cost_score();
                            // First unblocked dependent stays local (its
                            // inputs are warm here); the rest round-robin.
                            let w = if i == 0 { me } else { inner.next_rr() };
                            inner.push_ready(&mut st, w, dep, score);
                        }
                    }
                }
                Err(e) => {
                    let name = st.graph.tasks[tid as usize].spec.name;
                    st.graph.tasks[tid as usize].state = TaskState::Failed;
                    st.error.get_or_insert(format!("task `{name}` failed: {e}"));
                }
            }
        }
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DenseMatrix;

    fn add_op(delta: f32) -> TaskFn {
        Arc::new(move |ins: &[Arc<Block>]| {
            let m = ins[0].as_dense()?;
            Ok(vec![Block::Dense(m.map(|x| x + delta))])
        })
    }

    #[test]
    fn wide_fanout_executes_fully() {
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let mut outs = Vec::new();
        for i in 0..64 {
            let o = ex.submit(
                "fan",
                &[src],
                vec![BlockMeta::dense(2, 2)],
                CostHint::default(),
                16.0,
                add_op(i as f32),
            );
            outs.push(o[0]);
        }
        ex.barrier().unwrap();
        for (i, &o) in outs.iter().enumerate() {
            let v = ex.wait(o).unwrap();
            assert_eq!(v.as_dense().unwrap().get(0, 0), 1.0 + i as f32);
        }
        assert_eq!(ex.metrics().total_tasks(), 64);
    }

    #[test]
    fn deep_chain_is_ordered() {
        let ex = LocalExecutor::new(3);
        let mut cur = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        for _ in 0..100 {
            cur = ex.submit(
                "inc",
                &[cur],
                vec![BlockMeta::dense(1, 1)],
                CostHint::default(),
                4.0,
                add_op(1.0),
            )[0];
        }
        let v = ex.wait(cur).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 100.0);
    }

    #[test]
    fn task_error_poisons_runtime() {
        let ex = LocalExecutor::new(2);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let bad = ex.submit(
            "explode",
            &[src],
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            4.0,
            Arc::new(|_| anyhow::bail!("boom")),
        );
        assert!(ex.wait(bad[0]).is_err());
        assert!(ex.barrier().is_err());
    }

    #[test]
    fn arity_mismatch_detected() {
        let ex = LocalExecutor::new(1);
        let out = ex.submit(
            "liar",
            &[],
            vec![BlockMeta::dense(1, 1), BlockMeta::dense(1, 1)],
            CostHint::default(),
            0.0,
            Arc::new(|_| Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])),
        );
        assert!(ex.wait(out[0]).is_err());
    }

    #[test]
    fn collection_style_many_inputs() {
        let ex = LocalExecutor::new(4);
        let parts: Vec<DataId> = (0..32)
            .map(|i| ex.put_block(Block::Dense(DenseMatrix::full(1, 1, i as f32))))
            .collect();
        let sum = ex.submit(
            "reduce_all",
            &parts,
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            128.0,
            Arc::new(|ins: &[Arc<Block>]| {
                let s: f32 = ins.iter().map(|b| b.as_dense().unwrap().get(0, 0)).sum();
                Ok(vec![Block::Dense(DenseMatrix::full(1, 1, s))])
            }),
        );
        let v = ex.wait(sum[0]).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), (0..32).sum::<i32>() as f32);
    }

    #[test]
    fn batch_submit_one_lock_many_tasks() {
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let batch: Vec<TaskSubmit> = (0..128)
            .map(|i| TaskSubmit {
                name: "batched",
                reads: vec![src],
                out_metas: vec![BlockMeta::dense(1, 1)],
                hint: CostHint::default(),
                read_bytes: 4.0,
                body: TaskBody::Shared(add_op(i as f32)),
                fused_ops: 1,
            })
            .collect();
        let outs = ex.submit_batch(batch);
        assert_eq!(outs.len(), 128);
        ex.barrier().unwrap();
        for (i, o) in outs.iter().enumerate() {
            let v = ex.wait(o[0]).unwrap();
            assert_eq!(v.as_dense().unwrap().get(0, 0), i as f32);
        }
        assert_eq!(ex.metrics().total_tasks(), 128);
    }

    #[test]
    fn intra_batch_dependencies_resolve() {
        // Task 1 of the batch reads task 0's output: ids are allocated in
        // order, so this must wire a dependency, not race.
        let ex = LocalExecutor::new(2);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 1.0)));
        let first = TaskSubmit {
            name: "first",
            reads: vec![src],
            out_metas: vec![BlockMeta::dense(1, 1)],
            hint: CostHint::default(),
            read_bytes: 4.0,
            body: TaskBody::Shared(add_op(10.0)),
            fused_ops: 1,
        };
        // The output id of `first` is predictable: next data id after src+1.
        let first_out: DataId = src + 1;
        let second = TaskSubmit {
            name: "second",
            reads: vec![first_out],
            out_metas: vec![BlockMeta::dense(1, 1)],
            hint: CostHint::default(),
            read_bytes: 4.0,
            body: TaskBody::Shared(add_op(100.0)),
            fused_ops: 1,
        };
        let outs = ex.submit_batch(vec![first, second]);
        assert_eq!(outs[0][0], first_out);
        let v = ex.wait(outs[1][0]).unwrap();
        assert_eq!(v.as_dense().unwrap().get(0, 0), 111.0);
    }

    #[test]
    fn contention_stress_submitters_vs_waiters() {
        // Many threads submitting while others barrier/wait: the scheduler
        // must neither lose tasks nor deadlock (satellite: contention test).
        let ex = Arc::new(LocalExecutor::new(4));
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let n_threads = 6;
        let per_thread = 200;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let ex = Arc::clone(&ex);
            handles.push(std::thread::spawn(move || {
                let mut outs = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    let o = ex.submit(
                        "stress",
                        &[src],
                        vec![BlockMeta::dense(1, 1)],
                        CostHint::flops((i % 7) as f64 * 1e3),
                        4.0,
                        add_op((t * per_thread + i) as f32),
                    );
                    outs.push((o[0], (t * per_thread + i) as f32));
                    if i % 32 == 0 {
                        ex.barrier().unwrap();
                    }
                }
                for (id, want) in outs {
                    let v = ex.wait(id).unwrap();
                    assert_eq!(v.as_dense().unwrap().get(0, 0), want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        ex.barrier().unwrap();
        assert_eq!(
            ex.metrics().total_tasks(),
            (n_threads * per_thread) as u64
        );
    }

    #[test]
    fn owned_task_grants_inplace_only_for_dead_blocks() {
        use std::sync::atomic::AtomicBool;
        let ex = LocalExecutor::new(2);
        let kept = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let dead = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 2.0)));
        ex.retain(&[kept, dead]);
        // Gate the owned task behind a spinning predecessor so its claim —
        // where the grant decision happens — runs only after `dead`'s
        // handle is released.
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let gate_out = ex.submit(
            "gate",
            &[],
            vec![BlockMeta::dense(1, 1)],
            CostHint::default(),
            0.0,
            Arc::new(move |_ins: &[Arc<Block>]| {
                while !g.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
                Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])
            }),
        );
        // Ownership-aware task: adds 10 to every element of inputs it was
        // granted exclusively, so the grant decision is observable. `dead`
        // is the FIRST read — the only position eligible for a grant.
        let outs = ex.submit_batch(vec![TaskSubmit {
            name: "owned",
            reads: vec![dead, kept, gate_out[0]],
            out_metas: vec![
                BlockMeta::dense(2, 2),
                BlockMeta::dense(2, 2),
                BlockMeta::dense(1, 1),
            ],
            hint: CostHint::default(),
            read_bytes: 36.0,
            body: TaskBody::Owned(Arc::new(|ins: Vec<TaskInput>| {
                let mut outs = Vec::with_capacity(ins.len());
                for inp in ins {
                    let bump = if inp.is_owned() { 10.0 } else { 0.0 };
                    let mut d = inp.into_dense()?;
                    for x in d.data_mut() {
                        *x += bump;
                    }
                    outs.push(Block::Dense(d));
                }
                Ok(outs)
            })),
            fused_ops: 3,
        }]);
        // `dead`'s handle goes away while its reader is still pending: the
        // claim must hand the value over exclusively. `kept`'s handle stays.
        ex.release(&[dead]);
        gate.store(true, Ordering::SeqCst);
        ex.barrier().unwrap();
        let o = &outs[0];
        assert_eq!(ex.wait(o[0]).unwrap().as_dense().unwrap().get(0, 0), 12.0);
        assert_eq!(ex.wait(o[1]).unwrap().as_dense().unwrap().get(0, 0), 1.0);
        assert_eq!(ex.wait(o[2]).unwrap().as_dense().unwrap().get(0, 0), 0.0);
        // The granted block left the data table; the shared one survives.
        assert!(ex.wait(dead).is_err());
        assert!(ex.wait(kept).is_ok());
        let m = ex.metrics();
        assert_eq!(m.inplace_hits, 1);
        assert_eq!(m.tasks_fused, 2);
        // gate stored 4 B fresh; owned stored 36 B with 16 B reused.
        assert_eq!(m.bytes_allocated, 24);
    }

    #[test]
    fn budget_spills_lru_and_wait_faults_back() {
        // 2x2 f32 blocks are 16 B; budget of 3 blocks, 6 registered.
        let ex = LocalExecutor::with_options(LocalOptions {
            workers: 2,
            memory_budget_bytes: Some(48),
            ..Default::default()
        })
        .unwrap();
        let ids: Vec<DataId> = (0..6)
            .map(|i| ex.put_block(Block::Dense(DenseMatrix::full(2, 2, i as f32))))
            .collect();
        let m = ex.metrics();
        assert_eq!(m.blocks_spilled, 3, "oldest half pushed out");
        assert!(m.resident_bytes <= 48);
        assert!(m.spill_bytes > 0);
        // Every value still synchronizes — spilled ones fault from disk.
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(ex.wait(id).unwrap().as_dense().unwrap().get(0, 0), i as f32);
        }
        let m = ex.metrics();
        // Walking all six in put order faults every block once (the three
        // initially resident ones get spilled as the walk advances).
        assert_eq!(m.blocks_faulted, 6);
        assert_eq!(m.blocks_spilled, 9);
        assert!(m.resident_bytes <= 48, "faulting re-enforces the budget");
        // Each of the 6 blocks was written to disk exactly once (22 B
        // header + 16 B payload): re-spills of clean blocks write nothing.
        assert_eq!(m.spill_bytes, 6 * 38);
        assert_eq!(m.blocks_evicted, 0, "spilling is not eviction");
    }

    #[test]
    fn tasks_fault_spilled_inputs_transparently() {
        // Budget of ONE block: a 2-input task must fault both its inputs.
        let ex = LocalExecutor::with_options(LocalOptions {
            workers: 2,
            memory_budget_bytes: Some(16),
            ..Default::default()
        })
        .unwrap();
        let a = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let b = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 10.0)));
        let out = ex.submit(
            "sum2",
            &[a, b],
            vec![BlockMeta::dense(2, 2)],
            CostHint::default(),
            32.0,
            Arc::new(|ins: &[Arc<Block>]| {
                let mut acc = ins[0].as_dense()?.clone();
                acc.axpy(1.0, ins[1].as_dense()?)?;
                Ok(vec![Block::Dense(acc)])
            }),
        );
        assert_eq!(ex.wait(out[0]).unwrap().as_dense().unwrap().get(0, 0), 11.0);
        let m = ex.metrics();
        assert!(m.blocks_spilled >= 1 && m.blocks_faulted >= 1);
    }

    #[test]
    fn dead_spilled_blocks_unlink_files_and_teardown_removes_dir() {
        let dir = std::env::temp_dir().join(format!(
            "rustdslib_spilltest_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok(); // leftovers from aborted runs
        let ex = LocalExecutor::with_options(LocalOptions {
            workers: 1,
            memory_budget_bytes: Some(16),
            spill_dir: Some(dir.clone()),
        })
        .unwrap();
        let a = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
        let b = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 2.0))); // spills `a`
        // The store owns a uniquely-named subdirectory of the configured
        // parent — never the parent itself.
        let sub = std::fs::read_dir(&dir).unwrap().next().unwrap().unwrap().path();
        assert!(sub.join("d00000000.blk").exists());
        // `a` dies while spilled: refcount reclamation unlinks its file.
        ex.retain(&[a]);
        ex.release(&[a]);
        assert!(!sub.join("d00000000.blk").exists());
        assert!(ex.wait(a).is_err());
        assert_eq!(ex.wait(b).unwrap().as_dense().unwrap().get(0, 0), 2.0);
        let m = ex.metrics();
        assert_eq!(m.blocks_evicted, 1);
        drop(ex);
        assert!(!sub.exists(), "teardown removes the per-runtime spill subdirectory");
        assert!(dir.exists(), "the caller's parent directory is untouched");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pinned_blocks_are_never_spilled() {
        let ex = LocalExecutor::with_options(LocalOptions {
            workers: 1,
            memory_budget_bytes: Some(16),
            ..Default::default()
        })
        .unwrap();
        let a = ex.put_block(Block::Dense(DenseMatrix::full(2, 2, 7.0)));
        ex.pin(a);
        for i in 0..4 {
            ex.put_block(Block::Dense(DenseMatrix::full(2, 2, i as f32)));
        }
        // `a` stayed resident through all the budget pressure: waiting on
        // it must not count a fault.
        let before = ex.metrics().blocks_faulted;
        assert_eq!(ex.wait(a).unwrap().as_dense().unwrap().get(0, 0), 7.0);
        assert_eq!(ex.metrics().blocks_faulted, before);
    }

    #[test]
    fn fat_block_task_splits_across_workers_and_stays_bit_identical() {
        let _g = crate::kernels::split_guard();
        let old = crate::kernels::set_split_min(1024); // force splitting
        let ex = LocalExecutor::new(4);
        let am = DenseMatrix::from_fn(96, 64, |i, j| ((i * 31 + j * 7) % 13) as f32 - 6.0);
        let bm = DenseMatrix::from_fn(64, 80, |i, j| ((i * 5 + j * 11) % 9) as f32 * 0.5);
        // Oracle: the raw whole-block kernel, no splitting involved.
        let mut expect = DenseMatrix::zeros(96, 80);
        (crate::kernels::active().gemm_acc)(
            expect.data_mut(),
            am.data(),
            bm.data(),
            96,
            64,
            80,
        );
        // One fat gemm task on the executor: its worker splits the block
        // into row-range sub-tasks over the sibling deques.
        let ida = ex.put_block(Block::Dense(am.clone()));
        let idb = ex.put_block(Block::Dense(bm.clone()));
        let out = ex.submit(
            "fat_gemm",
            &[ida, idb],
            vec![BlockMeta::dense(96, 80)],
            CostHint::flops(2.0 * 96.0 * 64.0 * 80.0),
            (am.data().len() + bm.data().len()) as f64 * 4.0,
            Arc::new(|ins: &[Arc<Block>]| {
                let mut c = DenseMatrix::zeros(96, 80);
                c.gemm_acc(ins[0].as_dense()?, ins[1].as_dense()?)?;
                Ok(vec![Block::Dense(c)])
            }),
        );
        let got = ex.wait(out[0]).unwrap();
        assert_eq!(
            got.as_dense().unwrap(),
            &expect,
            "split execution must be bit-identical to the whole-block kernel"
        );
        assert!(
            ex.metrics().subtasks_spawned > 0,
            "a 96x64x80 gemm above a 1024-op threshold must split"
        );
        crate::kernels::set_split_min(old);
    }

    #[test]
    fn stealing_drains_unbalanced_queues() {
        // One giant batch lands round-robin; with 4 workers and heavily
        // skewed costs every task must still execute exactly once.
        let ex = LocalExecutor::new(4);
        let src = ex.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
        let batch: Vec<TaskSubmit> = (0..256)
            .map(|i| TaskSubmit {
                name: "skewed",
                reads: vec![src],
                out_metas: vec![BlockMeta::dense(1, 1)],
                hint: CostHint::flops(if i % 16 == 0 { 1e9 } else { 1.0 }),
                read_bytes: 4.0,
                body: TaskBody::Shared(add_op(1.0)),
                fused_ops: 1,
            })
            .collect();
        ex.submit_batch(batch);
        ex.barrier().unwrap();
        assert_eq!(ex.metrics().tasks_for("skewed"), 256);
    }
}
