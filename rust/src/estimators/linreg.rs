//! Ridge linear regression via distributed normal equations — one of the
//! "common mathematical operations" the paper's §6 says ds-arrays unlock
//! (`XᵀX` and `Xᵀy` need column access, painful with Datasets).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dsarray::DsArray;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future};

use super::Estimator;

pub struct LinearRegression {
    pub lambda: f32,
    pub fit_intercept: bool,
    /// (f, 1) weights after fit.
    pub weights: Option<DenseMatrix>,
    pub intercept: f32,
}

impl LinearRegression {
    pub fn new(lambda: f32, fit_intercept: bool) -> Self {
        Self {
            lambda,
            fit_intercept,
            weights: None,
            intercept: 0.0,
        }
    }
}

impl Default for LinearRegression {
    fn default() -> Self {
        Self::new(1e-6, true)
    }
}

impl Estimator for LinearRegression {
    fn fit(&mut self, x: &DsArray, y: Option<&DsArray>) -> Result<()> {
        let y = y.ok_or_else(|| anyhow::anyhow!("linear regression needs labels"))?;
        if y.shape() != (x.rows(), 1) {
            bail!("y must be {}x1, got {:?}", x.rows(), y.shape());
        }
        if y.block_shape().0 != x.block_shape().0 {
            bail!("y row blocking must match x (rechunk first)");
        }
        // Force lazy views once: gram/tn_matmul/mean_axis would otherwise
        // each materialize the view independently.
        let x = x.force()?;
        let x = &x;
        let y = y.force()?;
        let y = &y;
        let rt = x.runtime().clone();
        let n = x.rows() as f32;

        // Distributed: G = XᵀX (f×f), b = Xᵀy (f×1) — both via block-column
        // tasks; means for the intercept via axis reductions.
        let gram = x.gram()?;
        let xty = x.tn_matmul(y)?;
        let (g, b, mx, my) = if self.fit_intercept {
            let mx = x.mean_axis(0)?.collect()?; // 1×f
            let my = y.mean_axis(0)?.collect()?.get(0, 0);
            (gram.collect()?, xty.collect()?, mx, my)
        } else {
            (
                gram.collect()?,
                xty.collect()?,
                DenseMatrix::zeros(1, x.cols()),
                0.0,
            )
        };
        if rt.is_sim() {
            bail!("linear regression fit requires synchronization (local mode)");
        }

        // Centered normal equations: (G - n·mxᵀmx + λI) w = b - n·my·mxᵀ.
        let f = x.cols();
        let mut a = g;
        let mut rhs = b;
        if self.fit_intercept {
            for i in 0..f {
                for j in 0..f {
                    let v = a.get(i, j) - n * mx.get(0, i) * mx.get(0, j);
                    a.set(i, j, v);
                }
                let v = rhs.get(i, 0) - n * my * mx.get(0, i);
                rhs.set(i, 0, v);
            }
        }
        for i in 0..f {
            let v = a.get(i, i) + self.lambda.max(1e-9);
            a.set(i, i, v);
        }
        let w = a.solve_spd(&rhs)?;
        self.intercept = if self.fit_intercept {
            my - (0..f).map(|j| w.get(j, 0) * mx.get(0, j)).sum::<f32>()
        } else {
            0.0
        };
        self.weights = Some(w);
        Ok(())
    }

    fn predict(&self, x: &DsArray) -> Result<DsArray> {
        let w = self
            .weights
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("predict before fit"))?
            .clone();
        let b = self.intercept;
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        let w_fut = rt.put_block(Block::Dense(w));
        let gc = x.grid().1;
        let mut batch = Vec::with_capacity(x.grid().0);
        for i in 0..x.grid().0 {
            let mut reads = x.block_row(i);
            reads.push(w_fut);
            let rows = x.block_rows_at(i);
            batch.push(BatchTask::new(
                "linreg.predict",
                reads,
                vec![BlockMeta::dense(rows, 1)],
                CostHint::flops(2.0 * rows as f64 * x.cols() as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let w = ins[gc].to_dense()?;
                    let dense: Vec<DenseMatrix> = ins[..gc]
                        .iter()
                        .map(|bl| bl.to_dense())
                        .collect::<Result<_>>()?;
                    let refs: Vec<&DenseMatrix> = dense.iter().collect();
                    let panel = DenseMatrix::hstack(&refs)?;
                    let mut pred = panel.matmul(&w)?;
                    for v in pred.data_mut() {
                        *v += b;
                    }
                    Ok(vec![Block::Dense(pred)])
                }),
            ));
        }
        let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(rt, (x.rows(), 1), (x.block_shape().0, 1), blocks, false)
    }

    /// R² coefficient of determination.
    fn score(&self, x: &DsArray, y: &DsArray) -> Result<f64> {
        let pred = self.predict(x)?.collect()?;
        let truth = y.collect()?;
        let n = truth.rows() as f64;
        let mean: f64 = truth.data().iter().map(|&v| v as f64).sum::<f64>() / n;
        let ss_res: f64 = pred
            .data()
            .iter()
            .zip(truth.data())
            .map(|(&p, &t)| ((t - p) as f64).powi(2))
            .sum();
        let ss_tot: f64 = truth
            .data()
            .iter()
            .map(|&t| (t as f64 - mean).powi(2))
            .sum();
        Ok(1.0 - ss_res / ss_tot.max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::tasking::Runtime;
    use crate::util::rng::Xoshiro256;

    fn linear_data(
        rt: &Runtime,
        n: usize,
        f: usize,
        noise: f32,
        seed: u64,
    ) -> (DsArray, DsArray, Vec<f32>, f32) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let w: Vec<f32> = (0..f).map(|_| rng.next_normal()).collect();
        let b = 0.7;
        let xm = DenseMatrix::from_fn(n, f, |_, _| rng.next_normal());
        let ym = DenseMatrix::from_fn(n, 1, |i, _| {
            let dot: f32 = (0..f).map(|j| xm.get(i, j) * w[j]).sum();
            dot + b + rng.next_normal() * noise
        });
        let x = creation::from_matrix(rt, &xm, (8, 4)).unwrap();
        let y = creation::from_matrix(rt, &ym, (8, 1)).unwrap();
        (x, y, w, b)
    }

    #[test]
    fn recovers_true_weights_noiseless() {
        let rt = Runtime::local(2);
        let (x, y, w, b) = linear_data(&rt, 64, 6, 0.0, 1);
        let mut lr = LinearRegression::default();
        lr.fit(&x, Some(&y)).unwrap();
        let got = lr.weights.as_ref().unwrap();
        for (j, &wj) in w.iter().enumerate() {
            assert!((got.get(j, 0) - wj).abs() < 1e-2, "w[{j}]");
        }
        assert!((lr.intercept - b).abs() < 1e-2, "intercept {}", lr.intercept);
        assert!(lr.score(&x, &y).unwrap() > 0.999);
    }

    #[test]
    fn noisy_fit_still_generalizes() {
        let rt = Runtime::local(2);
        let (x, y, _, _) = linear_data(&rt, 96, 4, 0.1, 2);
        let mut lr = LinearRegression::new(1e-4, true);
        lr.fit(&x, Some(&y)).unwrap();
        assert!(lr.score(&x, &y).unwrap() > 0.9);
    }

    #[test]
    fn no_intercept_mode() {
        let rt = Runtime::local(2);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let xm = DenseMatrix::from_fn(32, 3, |_, _| rng.next_normal());
        let ym = DenseMatrix::from_fn(32, 1, |i, _| 2.0 * xm.get(i, 0) - xm.get(i, 2));
        let x = creation::from_matrix(&rt, &xm, (8, 3)).unwrap();
        let y = creation::from_matrix(&rt, &ym, (8, 1)).unwrap();
        let mut lr = LinearRegression::new(1e-6, false);
        lr.fit(&x, Some(&y)).unwrap();
        let w = lr.weights.as_ref().unwrap();
        assert!((w.get(0, 0) - 2.0).abs() < 1e-3);
        assert!((w.get(1, 0)).abs() < 1e-3);
        assert!((w.get(2, 0) + 1.0).abs() < 1e-3);
        assert_eq!(lr.intercept, 0.0);
    }

    #[test]
    fn rejects_missing_or_misaligned_labels() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (8, 2), (4, 2)).unwrap();
        let mut lr = LinearRegression::default();
        assert!(lr.fit(&x, None).is_err());
        let bad_y = creation::zeros(&rt, (8, 1), (2, 1)).unwrap();
        assert!(lr.fit(&x, Some(&bad_y)).is_err());
    }
}
