//! PCA via the distributed Gram matrix + local power iteration with
//! deflation — "matrix decomposition" as a natural ds-array operation
//! (paper §6). The heavy O(n·f²) Gram runs distributed; the O(f²·q)
//! eigen-extraction is master-side (f is small by assumption).

use anyhow::{bail, Result};

use crate::dsarray::DsArray;
use crate::storage::DenseMatrix;
use crate::util::rng::Xoshiro256;

use super::Estimator;

pub struct Pca {
    /// Number of components to extract.
    pub n_components: usize,
    pub seed: u64,
    /// (q, f) principal axes, row per component, after fit.
    pub components: Option<DenseMatrix>,
    /// Explained variance per component.
    pub explained_variance: Vec<f32>,
    /// (1, f) feature means, after fit.
    pub mean: Option<DenseMatrix>,
}

impl Pca {
    pub fn new(n_components: usize) -> Self {
        Self {
            n_components,
            seed: 17,
            components: None,
            explained_variance: Vec::new(),
            mean: None,
        }
    }

    /// Power iteration with deflation on a symmetric PSD matrix.
    fn top_eigs(cov: &DenseMatrix, q: usize, seed: u64) -> Result<(DenseMatrix, Vec<f32>)> {
        let f = cov.rows();
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut work = cov.clone();
        let mut comps = DenseMatrix::zeros(q, f);
        let mut vals = Vec::with_capacity(q);
        for c in 0..q {
            let mut v: Vec<f32> = (0..f).map(|_| rng.next_normal()).collect();
            let mut lambda = 0.0f32;
            for _ in 0..300 {
                // w = A v
                let mut w = vec![0.0f32; f];
                for i in 0..f {
                    let row = work.row(i);
                    w[i] = row.iter().zip(&v).map(|(&a, &b)| a * b).sum();
                }
                let norm = w.iter().map(|&x| x * x).sum::<f32>().sqrt();
                if norm < 1e-12 {
                    break;
                }
                for x in &mut w {
                    *x /= norm;
                }
                let delta: f32 = w
                    .iter()
                    .zip(&v)
                    .map(|(&a, &b)| (a - b).abs())
                    .fold(0.0, f32::max);
                v = w;
                lambda = norm;
                if delta < 1e-7 {
                    break;
                }
            }
            comps.row_mut(c).copy_from_slice(&v);
            vals.push(lambda);
            // Deflate: A -= λ v vᵀ.
            for i in 0..f {
                for j in 0..f {
                    let x = work.get(i, j) - lambda * v[i] * v[j];
                    work.set(i, j, x);
                }
            }
        }
        Ok((comps, vals))
    }

    /// Project samples onto the fitted components: (rows, q) ds-array.
    pub fn transform(&self, x: &DsArray) -> Result<DsArray> {
        let comps = self
            .components
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("transform before fit"))?;
        let mean = self.mean.as_ref().unwrap();
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        // Center then project: (X - μ) Wᵀ. The centering is a deferred
        // fused expression — matmul materializes it in one task per block,
        // so no centered copy of X is ever staged separately.
        let mean_arr =
            crate::dsarray::creation::from_matrix(&rt, mean, (1, x.block_shape().1))?;
        let centered = x.sub_row_broadcast(&mean_arr)?;
        let wt = comps.transpose(); // (f, q)
        let w_arr = crate::dsarray::creation::from_matrix(&rt, &wt, (x.block_shape().1, wt.cols()))?;
        centered.matmul(&w_arr)
    }
}

impl Estimator for Pca {
    fn fit(&mut self, x: &DsArray, _y: Option<&DsArray>) -> Result<()> {
        if self.n_components == 0 || self.n_components > x.cols() {
            bail!(
                "n_components {} invalid for {} features",
                self.n_components,
                x.cols()
            );
        }
        let rt = x.runtime();
        if rt.is_sim() {
            bail!("PCA fit requires synchronization (local mode)");
        }
        // Force lazy views once for the gram + mean passes.
        let x = x.force()?;
        let x = &x;
        let n = x.rows() as f32;
        // Distributed: G = XᵀX and column means.
        let g = x.gram()?.collect()?;
        let mean = x.mean_axis(0)?.collect()?;
        // Covariance = G/n - μᵀμ.
        let f = x.cols();
        let cov = DenseMatrix::from_fn(f, f, |i, j| {
            g.get(i, j) / n - mean.get(0, i) * mean.get(0, j)
        });
        let (comps, vals) = Self::top_eigs(&cov, self.n_components, self.seed)?;
        self.components = Some(comps);
        self.explained_variance = vals;
        self.mean = Some(mean);
        Ok(())
    }

    /// First-component projection per sample (rows×1).
    fn predict(&self, x: &DsArray) -> Result<DsArray> {
        let t = self.transform(x)?;
        t.slice_cols(0, 1)
    }

    /// Fraction of total variance explained by the kept components.
    fn score(&self, x: &DsArray, _y: &DsArray) -> Result<f64> {
        if self.components.is_none() {
            bail!("score before fit");
        }
        let x = x.force()?;
        let x = &x;
        let n = x.rows() as f32;
        let g = x.gram()?.collect()?;
        let mean = x.mean_axis(0)?.collect()?;
        let total: f32 = (0..x.cols())
            .map(|i| g.get(i, i) / n - mean.get(0, i) * mean.get(0, i))
            .sum();
        let kept: f32 = self.explained_variance.iter().sum();
        Ok((kept / total.max(1e-12)) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::tasking::Runtime;

    /// Data stretched along a known direction.
    fn stretched(rt: &Runtime, n: usize) -> (DsArray, DenseMatrix) {
        let mut rng = Xoshiro256::seed_from_u64(8);
        // Principal axis (1, 1, 0)/√2 with sd 5; others sd 0.3.
        let m = DenseMatrix::from_fn(n, 3, |_, _| rng.next_normal());
        let mut data = DenseMatrix::zeros(n, 3);
        for i in 0..n {
            let t = m.get(i, 0) * 5.0;
            data.set(i, 0, t * 0.7071 + m.get(i, 1) * 0.3 + 1.0);
            data.set(i, 1, t * 0.7071 - m.get(i, 1) * 0.3 - 2.0);
            data.set(i, 2, m.get(i, 2) * 0.3);
        }
        (creation::from_matrix(rt, &data, (16, 3)).unwrap(), data)
    }

    #[test]
    fn finds_dominant_axis() {
        let rt = Runtime::local(2);
        let (x, _) = stretched(&rt, 128);
        let mut pca = Pca::new(2);
        pca.fit(&x, None).unwrap();
        let c = pca.components.as_ref().unwrap();
        // First component ≈ ±(0.7071, 0.7071, 0).
        let (a, b, z) = (c.get(0, 0), c.get(0, 1), c.get(0, 2));
        assert!((a.abs() - 0.7071).abs() < 0.05, "a={a}");
        assert!((b.abs() - 0.7071).abs() < 0.05, "b={b}");
        assert!(z.abs() < 0.1, "z={z}");
        assert!(a * b > 0.0, "components aligned");
        // Variances sorted descending.
        assert!(pca.explained_variance[0] > pca.explained_variance[1]);
        // Nearly all variance in 2 components.
        let y = creation::zeros(&rt, (128, 1), (16, 1)).unwrap();
        assert!(pca.score(&x, &y).unwrap() > 0.95);
    }

    #[test]
    fn transform_decorrelates() {
        let rt = Runtime::local(2);
        let (x, _) = stretched(&rt, 96);
        let mut pca = Pca::new(2);
        pca.fit(&x, None).unwrap();
        let t = pca.transform(&x).unwrap().collect().unwrap();
        assert_eq!((t.rows(), t.cols()), (96, 2));
        // Projected columns are uncorrelated and mean ~0.
        let n = 96.0f32;
        let m0: f32 = (0..96).map(|i| t.get(i, 0)).sum::<f32>() / n;
        let m1: f32 = (0..96).map(|i| t.get(i, 1)).sum::<f32>() / n;
        assert!(m0.abs() < 0.2 && m1.abs() < 0.2);
        let cov01: f32 =
            (0..96).map(|i| (t.get(i, 0) - m0) * (t.get(i, 1) - m1)).sum::<f32>() / n;
        let v0: f32 = (0..96).map(|i| (t.get(i, 0) - m0).powi(2)).sum::<f32>() / n;
        let v1: f32 = (0..96).map(|i| (t.get(i, 1) - m1).powi(2)).sum::<f32>() / n;
        assert!(cov01.abs() / (v0 * v1).sqrt() < 0.1, "corr {}", cov01);
    }

    #[test]
    fn fit_on_a_row_slice_view() {
        // Slicing instead of copying: fit on an unaligned row-slice view;
        // gram/mean force it internally.
        let rt = Runtime::local(2);
        let (x, _) = stretched(&rt, 128);
        let v = x.slice_rows(3, 125).unwrap();
        assert!(v.is_view());
        let mut pca = Pca::new(2);
        pca.fit(&v, None).unwrap();
        let c = pca.components.as_ref().unwrap();
        let (a, b) = (c.get(0, 0), c.get(0, 1));
        assert!((a.abs() - 0.7071).abs() < 0.05, "a={a}");
        assert!(a * b > 0.0);
        // predict slices the transform with a zero-copy column view.
        let p = pca.predict(&v).unwrap();
        assert_eq!(p.shape(), (122, 1));
    }

    #[test]
    fn fit_then_score_reuses_gram_via_cse_and_matches_off() {
        let off = Runtime::local(2);
        let (x_off, data) = stretched(&off, 64);
        let mut p_off = Pca::new(2);
        p_off.fit(&x_off, None).unwrap();
        let y_off = creation::zeros(&off, (64, 1), (16, 1)).unwrap();
        let s_off = p_off.score(&x_off, &y_off).unwrap();

        let full = Runtime::local(2).with_optimizer(crate::plan::Level::Full);
        let x = creation::from_matrix(&full, &data, (16, 3)).unwrap();
        let mut p = Pca::new(2);
        p.fit(&x, None).unwrap();
        assert_eq!(
            p.components
                .as_ref()
                .unwrap()
                .max_abs_diff(p_off.components.as_ref().unwrap()),
            0.0,
            "components bit-identical across optimizer levels"
        );
        assert_eq!(
            p.mean.as_ref().unwrap().max_abs_diff(p_off.mean.as_ref().unwrap()),
            0.0
        );

        // score() recomputes X'X on the same single-assignment block ids:
        // the memo entry from fit survives the intervening collect epochs
        // (CSE_MAX_AGE) and short-circuits the gram to zero tasks.
        let deduped_after_fit = full.metrics().tasks_deduped;
        let y = creation::zeros(&full, (64, 1), (16, 1)).unwrap();
        let s = p.score(&x, &y).unwrap();
        assert_eq!(s, s_off, "score bit-identical across optimizer levels");
        assert!(
            full.metrics().tasks_deduped > deduped_after_fit,
            "score's gram must hit fit's memo entry"
        );
        assert!(full.metrics().total_tasks() < off.metrics().total_tasks());
    }

    #[test]
    fn rejects_bad_component_count() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (8, 2), (4, 2)).unwrap();
        assert!(Pca::new(0).fit(&x, None).is_err());
        assert!(Pca::new(3).fit(&x, None).is_err());
    }
}
