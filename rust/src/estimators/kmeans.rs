//! K-means clustering (paper §5.5) — the control experiment: its
//! parallelization (per-partition partial sums + reduction + center update)
//! is identical on ds-arrays and Datasets, so performance should match.
//!
//! Hot path: the fused Pallas `kmeans_assign` artifact via PJRT when blocks
//! fit the canonical shapes (k ≤ 8, features ≤ 128), tiled over sample rows
//! on the Rust side; native fallback otherwise. The whole iteration is a
//! task graph (partials → tree reduction → center update task), so the same
//! code runs under the local executor and the cluster simulator.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dataset::Dataset;
use crate::dsarray::DsArray;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future, Runtime};
use crate::util::rng::Xoshiro256;

use super::Estimator;

/// Arity of the partial-sum reduction tree.
const REDUCE_ARITY: usize = 8;

#[derive(Clone, Debug)]
pub struct KMeansConfig {
    pub k: usize,
    pub max_iter: usize,
    /// Stop when the relative inertia improvement drops below this
    /// (ignored in sim mode, where nothing can synchronize).
    pub tol: f64,
    pub seed: u64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        Self {
            k: 8,
            max_iter: 10,
            tol: 1e-4,
            seed: 42,
        }
    }
}

pub struct KMeans {
    pub cfg: KMeansConfig,
    /// (k, f) fitted centers (local mode).
    pub centers: Option<DenseMatrix>,
    /// Inertia (sum of squared distances) at the last iteration.
    pub inertia: f64,
    /// Iterations actually executed.
    pub n_iter: usize,
}

impl KMeans {
    pub fn new(cfg: KMeansConfig) -> Self {
        Self {
            cfg,
            centers: None,
            inertia: f64::INFINITY,
            n_iter: 0,
        }
    }

    /// One assignment pass: per block-row partial task (+ tree reduction).
    /// Returns futures of (psum (k,f), pcount (1,k), pssd (1,1)) reduced
    /// over the whole array.
    fn assignment_round(
        rt: &Runtime,
        x: &DsArray,
        centers_fut: Future,
        k: usize,
    ) -> (Future, Future, Future) {
        let partials = Self::assignment_partials(rt, x, centers_fut, k);
        reduce_triples(rt, partials, k, x.cols())
    }

    /// The per-block-row partial batch alone (no reduction): one
    /// `kmeans.partial` task per block-row, each emitting a
    /// (psum, pcount, pssd) triple.
    fn assignment_partials(
        rt: &Runtime,
        x: &DsArray,
        centers_fut: Future,
        k: usize,
    ) -> Vec<(Future, Future, Future)> {
        let f = x.cols();
        // One partial task per block-row, submitted as one batch.
        let mut batch = Vec::with_capacity(x.grid().0);
        for i in 0..x.grid().0 {
            let mut reads = x.block_row(i);
            let rows = x.block_rows_at(i);
            reads.push(centers_fut);
            let metas = vec![
                BlockMeta::dense(k, f),
                BlockMeta::dense(1, k),
                BlockMeta::dense(1, 1),
            ];
            let bytes: f64 = reads.iter().map(|r| r.meta.bytes() as f64).sum();
            // distances: 3*rows*f*k flops, psum matmul: 2*rows*k*f.
            let flops = 5.0 * rows as f64 * f as f64 * k as f64;
            let gc = x.grid().1;
            batch.push(BatchTask::new(
                "kmeans.partial",
                reads,
                metas,
                CostHint::flops(flops).with_bytes(bytes),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let centers = ins[gc].to_dense()?;
                    // Assemble the full-width sample panel.
                    let dense: Vec<DenseMatrix> = ins[..gc]
                        .iter()
                        .map(|b| b.to_dense())
                        .collect::<Result<_>>()?;
                    let refs: Vec<&DenseMatrix> = dense.iter().collect();
                    let panel = DenseMatrix::hstack(&refs)?;
                    let (psum, pcount, pssd) = assign_block(&panel, &centers)?;
                    Ok(vec![
                        Block::Dense(psum),
                        Block::Dense(pcount),
                        Block::Dense(DenseMatrix::full(1, 1, pssd)),
                    ])
                }),
            ));
        }
        rt.submit_batch(batch)
            .into_iter()
            .map(|out| (out[0], out[1], out[2]))
            .collect()
    }

    /// Submit the center-update task: new centers from reduced partials
    /// (empty clusters keep their previous center, like dislib).
    fn update_round(
        rt: &Runtime,
        reduced: (Future, Future, Future),
        centers_fut: Future,
        k: usize,
        f: usize,
    ) -> Future {
        let (psum, pcount, _) = reduced;
        let out = rt.submit(
            "kmeans.update",
            &[psum, pcount, centers_fut],
            vec![BlockMeta::dense(k, f)],
            CostHint::flops((k * f) as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                let psum = ins[0].to_dense()?;
                let pcount = ins[1].to_dense()?;
                let old = ins[2].to_dense()?;
                let mut new = old.clone();
                for kk in 0..psum.rows() {
                    let n = pcount.get(0, kk);
                    if n > 0.0 {
                        for j in 0..psum.cols() {
                            new.set(kk, j, psum.get(kk, j) / n);
                        }
                    }
                }
                Ok(vec![Block::Dense(new)])
            }),
        );
        out[0]
    }

    /// Plan-layer composed iteration tail (`Level::Full` only): the last
    /// reduction level and the center update run as **one**
    /// `kmeans.reduce_update` task instead of a `kmeans.reduce` +
    /// `kmeans.update` pair — the reduced psum/pcount are consumed while
    /// still cache-hot, and one scheduler round-trip per iteration
    /// disappears. Arithmetic is identical to the eager pair (same axpy
    /// fold, then the same per-cluster division), so trajectories stay
    /// bit-identical. Returns (new centers (k,f), pssd (1,1)).
    fn reduce_update_round(
        rt: &Runtime,
        mut level: Vec<(Future, Future, Future)>,
        centers_fut: Future,
        k: usize,
        f: usize,
    ) -> (Future, Future) {
        // Tree-reduce until one fan-in's worth of triples remains, with the
        // exact eager topology, then fuse the final level into the update.
        while level.len() > REDUCE_ARITY {
            level = reduce_one_level(rt, level, k, f);
        }
        let n = level.len();
        let mut reads = Vec::with_capacity(n * 3 + 1);
        for &(s, c, d) in &level {
            reads.push(s);
            reads.push(c);
            reads.push(d);
        }
        reads.push(centers_fut);
        let metas = vec![BlockMeta::dense(k, f), BlockMeta::dense(1, 1)];
        let task = BatchTask::new(
            "kmeans.reduce_update",
            reads,
            metas,
            CostHint::flops((n * k * (f + 1) + k * f) as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                let mut psum = ins[0].to_dense()?;
                let mut pcount = ins[1].to_dense()?;
                let mut pssd = ins[2].to_dense()?;
                for triple in ins[3..3 * n].chunks(3) {
                    psum.axpy(1.0, &triple[0].to_dense()?)?;
                    pcount.axpy(1.0, &triple[1].to_dense()?)?;
                    pssd.axpy(1.0, &triple[2].to_dense()?)?;
                }
                let old = ins[3 * n].to_dense()?;
                let mut new = old.clone();
                for kk in 0..psum.rows() {
                    let cnt = pcount.get(0, kk);
                    if cnt > 0.0 {
                        for j in 0..psum.cols() {
                            new.set(kk, j, psum.get(kk, j) / cnt);
                        }
                    }
                }
                Ok(vec![Block::Dense(new), Block::Dense(pssd)])
            }),
        )
        .with_fused_ops(2);
        let out = rt.submit_batch(vec![task]).remove(0);
        (out[0], out[1])
    }

    /// Build the full iteration graph. In local mode, synchronizes per
    /// iteration for the tolerance check; in sim mode runs `max_iter`
    /// fully asynchronous rounds.
    pub fn fit_dsarray(&mut self, x: &DsArray) -> Result<()> {
        // Lazy views (slices, train/test splits) materialize once up front;
        // canonical inputs pass through for free.
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        let k = self.cfg.k;
        let f = x.cols();
        if k == 0 || k > x.rows() {
            bail!("k={k} invalid for {} samples", x.rows());
        }
        // Init: random centers in the unit cube (dislib default is random).
        let mut rng = Xoshiro256::seed_from_u64(self.cfg.seed);
        let init = DenseMatrix::from_fn(k, f, |_, _| rng.next_f32());
        let mut centers_fut = rt.put_block(Block::Dense(init));

        let mut last = f64::INFINITY;
        self.n_iter = 0;
        for _ in 0..self.cfg.max_iter {
            let ssd_fut = if rt.planner().fuse_enabled() {
                // Plan layer on: the final reduce level and the center
                // update run as one composed task per iteration.
                let partials = Self::assignment_partials(&rt, x, centers_fut, k);
                let (new_centers, ssd) =
                    Self::reduce_update_round(&rt, partials, centers_fut, k, f);
                centers_fut = new_centers;
                ssd
            } else {
                let reduced = Self::assignment_round(&rt, x, centers_fut, k);
                centers_fut = Self::update_round(&rt, reduced, centers_fut, k, f);
                reduced.2
            };
            self.n_iter += 1;
            if !rt.is_sim() {
                let ssd = rt.wait(ssd_fut)?.to_dense()?.get(0, 0) as f64;
                self.inertia = ssd;
                if last.is_finite() && (last - ssd).abs() <= self.cfg.tol * last.max(1e-12) {
                    break;
                }
                last = ssd;
            }
        }
        if !rt.is_sim() {
            self.centers = Some(rt.wait(centers_fut)?.to_dense()?.clone());
        }
        Ok(())
    }

    /// Dataset-path fit (the baseline): identical parallelization, one
    /// partial task per Subset — the paper's point is that the curves match.
    pub fn fit_dataset(&mut self, ds: &Dataset) -> Result<()> {
        let rt = ds.runtime().clone();
        let k = self.cfg.k;
        let f = ds.n_features();
        let mut rng = Xoshiro256::seed_from_u64(self.cfg.seed);
        let init = DenseMatrix::from_fn(k, f, |_, _| rng.next_f32());
        let mut centers_fut = rt.put_block(Block::Dense(init));

        let mut last = f64::INFINITY;
        self.n_iter = 0;
        for _ in 0..self.cfg.max_iter {
            // Per-Subset partials (one batch per iteration).
            let mut batch = Vec::with_capacity(ds.n_subsets());
            for i in 0..ds.n_subsets() {
                let s = ds.subset(i);
                let reads = vec![s.samples, centers_fut];
                let rows = s.n_samples();
                let metas = vec![
                    BlockMeta::dense(k, f),
                    BlockMeta::dense(1, k),
                    BlockMeta::dense(1, 1),
                ];
                batch.push(BatchTask::new(
                    "kmeans.partial",
                    reads,
                    metas,
                    CostHint::flops(5.0 * rows as f64 * f as f64 * k as f64)
                        .with_bytes(s.samples.meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let panel = ins[0].to_dense()?;
                        let centers = ins[1].to_dense()?;
                        let (psum, pcount, pssd) = assign_block(&panel, &centers)?;
                        Ok(vec![
                            Block::Dense(psum),
                            Block::Dense(pcount),
                            Block::Dense(DenseMatrix::full(1, 1, pssd)),
                        ])
                    }),
                ));
            }
            let partials: Vec<(Future, Future, Future)> = rt
                .submit_batch(batch)
                .into_iter()
                .map(|out| (out[0], out[1], out[2]))
                .collect();
            // Same tree reduction + update as the ds-array path.
            let reduced = reduce_triples(&rt, partials, k, f);
            centers_fut = Self::update_round(&rt, reduced, centers_fut, k, f);
            self.n_iter += 1;
            if !rt.is_sim() {
                let ssd = rt.wait(reduced.2)?.to_dense()?.get(0, 0) as f64;
                self.inertia = ssd;
                if last.is_finite() && (last - ssd).abs() <= self.cfg.tol * last.max(1e-12) {
                    break;
                }
                last = ssd;
            }
        }
        if !rt.is_sim() {
            self.centers = Some(rt.wait(centers_fut)?.to_dense()?.clone());
        }
        Ok(())
    }
}

/// Reduce partial triples with the shared tree topology; each tree level
/// is submitted as one batch.
fn reduce_triples(
    rt: &Runtime,
    mut level: Vec<(Future, Future, Future)>,
    k: usize,
    f: usize,
) -> (Future, Future, Future) {
    while level.len() > 1 {
        level = reduce_one_level(rt, level, k, f);
    }
    level[0]
}

/// One tree level of the triple reduction: merge `REDUCE_ARITY`-sized
/// chunks with `kmeans.reduce` tasks, pass lone stragglers through.
fn reduce_one_level(
    rt: &Runtime,
    level: Vec<(Future, Future, Future)>,
    k: usize,
    f: usize,
) -> Vec<(Future, Future, Future)> {
    let mut next = Vec::with_capacity(level.len().div_ceil(REDUCE_ARITY));
    let mut batch = Vec::new();
    for chunk in level.chunks(REDUCE_ARITY) {
        if chunk.len() == 1 {
            next.push(Some(chunk[0]));
            continue;
        }
        next.push(None); // filled from the batch below, in order
        let mut reads = Vec::with_capacity(chunk.len() * 3);
        for &(s, c, d) in chunk {
            reads.push(s);
            reads.push(c);
            reads.push(d);
        }
        let metas = vec![
            BlockMeta::dense(k, f),
            BlockMeta::dense(1, k),
            BlockMeta::dense(1, 1),
        ];
        batch.push(BatchTask::new(
            "kmeans.reduce",
            reads,
            metas,
            CostHint::flops((chunk.len() * k * (f + 1)) as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                let mut psum = ins[0].to_dense()?;
                let mut pcount = ins[1].to_dense()?;
                let mut pssd = ins[2].to_dense()?;
                for triple in ins[3..].chunks(3) {
                    psum.axpy(1.0, &triple[0].to_dense()?)?;
                    pcount.axpy(1.0, &triple[1].to_dense()?)?;
                    pssd.axpy(1.0, &triple[2].to_dense()?)?;
                }
                Ok(vec![
                    Block::Dense(psum),
                    Block::Dense(pcount),
                    Block::Dense(pssd),
                ])
            }),
        ));
    }
    let mut outs = rt.submit_batch(batch).into_iter();
    next.into_iter()
        .map(|slot| {
            slot.unwrap_or_else(|| {
                let out = outs.next().expect("one batch output per merged chunk");
                (out[0], out[1], out[2])
            })
        })
        .collect()
}

/// Per-block assignment: PJRT fused kernel when shapes fit (tiled over
/// 128-row chunks), native fallback otherwise.
pub(crate) fn assign_block(
    panel: &DenseMatrix,
    centers: &DenseMatrix,
) -> Result<(DenseMatrix, DenseMatrix, f32)> {
    let (k, f) = (centers.rows(), centers.cols());
    if k <= 8 && f <= 128 {
        if let Some(svc) = crate::runtime::global() {
            let mut psum = DenseMatrix::zeros(k, f);
            let mut pcount = DenseMatrix::zeros(1, k);
            let mut pssd = 0.0f32;
            let mut r0 = 0;
            while r0 < panel.rows() {
                let rows = (panel.rows() - r0).min(128);
                let chunk = panel.slice(r0, 0, rows, f)?;
                let (s, c, d) = crate::runtime::exec::kmeans_assign(svc, &chunk, centers)?;
                psum.axpy(1.0, &s)?;
                pcount.axpy(1.0, &c)?;
                pssd += d;
                r0 += rows;
            }
            return Ok((psum, pcount, pssd));
        }
    }
    assign_block_native(panel, centers)
}

/// Native oracle/fallback for the assignment step.
pub(crate) fn assign_block_native(
    panel: &DenseMatrix,
    centers: &DenseMatrix,
) -> Result<(DenseMatrix, DenseMatrix, f32)> {
    let (k, f) = (centers.rows(), centers.cols());
    // Kernel-layer distance micro-kernel (SIMD when available; scalar and
    // SIMD tables are bit-identical, so assignments never diverge).
    let ker = crate::kernels::active();
    crate::kernels::record_hit(ker);
    let mut psum = DenseMatrix::zeros(k, f);
    let mut pcount = DenseMatrix::zeros(1, k);
    let mut pssd = 0.0f64;
    for i in 0..panel.rows() {
        let row = panel.row(i);
        let mut best = (f32::INFINITY, 0usize);
        for kk in 0..k {
            let d2 = (ker.dist2)(row, centers.row(kk));
            if d2 < best.0 {
                best = (d2, kk);
            }
        }
        pssd += best.0 as f64;
        pcount.set(0, best.1, pcount.get(0, best.1) + 1.0);
        let dst = psum.row_mut(best.1);
        for (d, &v) in dst.iter_mut().zip(row) {
            *d += v;
        }
    }
    Ok((psum, pcount, pssd as f32))
}

impl Estimator for KMeans {
    fn fit(&mut self, x: &DsArray, _y: Option<&DsArray>) -> Result<()> {
        self.fit_dsarray(x)
    }

    /// Cluster label per sample, returned as a new rows×1 ds-array (the
    /// §4.3 usability fix: predict returns fresh distributed data).
    fn predict(&self, x: &DsArray) -> Result<DsArray> {
        let centers = self
            .centers
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("predict before fit"))?
            .clone();
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        let gc = x.grid().1;
        let centers_fut = rt.put_block(Block::Dense(centers));
        let mut batch = Vec::with_capacity(x.grid().0);
        for i in 0..x.grid().0 {
            let mut reads = x.block_row(i);
            reads.push(centers_fut);
            let rows = x.block_rows_at(i);
            batch.push(BatchTask::new(
                "kmeans.predict",
                reads,
                vec![BlockMeta::dense(rows, 1)],
                CostHint::flops(3.0 * rows as f64 * x.cols() as f64 * self.cfg.k as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let centers = ins[gc].to_dense()?;
                    let dense: Vec<DenseMatrix> = ins[..gc]
                        .iter()
                        .map(|b| b.to_dense())
                        .collect::<Result<_>>()?;
                    let refs: Vec<&DenseMatrix> = dense.iter().collect();
                    let panel = DenseMatrix::hstack(&refs)?;
                    let ker = crate::kernels::active();
                    crate::kernels::record_hit(ker);
                    let mut labels = DenseMatrix::zeros(panel.rows(), 1);
                    for r in 0..panel.rows() {
                        let row = panel.row(r);
                        let mut best = (f32::INFINITY, 0usize);
                        for kk in 0..centers.rows() {
                            let d2 = (ker.dist2)(row, centers.row(kk));
                            if d2 < best.0 {
                                best = (d2, kk);
                            }
                        }
                        labels.set(r, 0, best.1 as f32);
                    }
                    Ok(vec![Block::Dense(labels)])
                }),
            ));
        }
        let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(rt, (x.rows(), 1), (x.block_shape().0, 1), blocks, false)
    }

    /// Negative inertia on x (higher is better), ignoring y.
    fn score(&self, x: &DsArray, _y: &DsArray) -> Result<f64> {
        let centers = self
            .centers
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("score before fit"))?;
        let collected = x.collect()?;
        let (_, _, ssd) = assign_block_native(&collected, centers)?;
        Ok(-(ssd as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::tasking::SimConfig;

    /// Two tight, well-separated blobs.
    fn blobs(rt: &Runtime, n: usize, f: usize, bs: (usize, usize)) -> DsArray {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let m = DenseMatrix::from_fn(n, f, |i, _| {
            let base = if i < n / 2 { 4.0 } else { -4.0 };
            base + rng.next_normal() * 0.2
        });
        creation::from_matrix(rt, &m, bs).unwrap()
    }

    #[test]
    fn converges_on_separated_blobs() {
        let rt = Runtime::local(2);
        let x = blobs(&rt, 60, 6, (16, 6));
        let mut km = KMeans::new(KMeansConfig {
            k: 2,
            max_iter: 20,
            tol: 1e-6,
            seed: 3,
        });
        km.fit_dsarray(&x).unwrap();
        let c = km.centers.as_ref().unwrap();
        // One center near +4, the other near -4 (in every coordinate).
        let m0 = c.row(0)[0];
        let m1 = c.row(1)[0];
        assert!(
            (m0 - 4.0).abs() < 0.5 && (m1 + 4.0).abs() < 0.5
                || (m0 + 4.0).abs() < 0.5 && (m1 - 4.0).abs() < 0.5,
            "centers {m0} {m1}"
        );
        assert!(km.inertia < 60.0, "inertia {}", km.inertia);
    }

    #[test]
    fn fit_on_deferred_fused_input_matches_eager() {
        // K-means over a deferred `2x + 1` chain must equal K-means over
        // the materialized equivalent, and the chain must fuse to one task
        // per block (memoized across fit and predict).
        let rt = Runtime::local(2);
        let x = blobs(&rt, 60, 6, (16, 6));
        let lazy = x.mul_scalar(2.0).unwrap().add_scalar(1.0).unwrap();
        let eager = lazy.force().unwrap();
        let cfg = KMeansConfig {
            k: 2,
            max_iter: 20,
            tol: 1e-6,
            seed: 3,
        };
        let mut km_lazy = KMeans::new(cfg.clone());
        km_lazy.fit_dsarray(&lazy).unwrap();
        let mut km_eager = KMeans::new(cfg);
        km_eager.fit_dsarray(&eager).unwrap();
        assert!((km_lazy.inertia - km_eager.inertia).abs() < 1e-3);
        let p1 = km_lazy.predict(&lazy).unwrap().collect().unwrap();
        let p2 = km_eager.predict(&eager).unwrap().collect().unwrap();
        assert_eq!(p1, p2);
        // One fused materialization total for the whole lazy flow.
        assert_eq!(
            rt.metrics().tasks_for("dsarray.ew.fused"),
            x.n_blocks() as u64
        );
    }

    #[test]
    fn full_optimizer_fuses_update_and_matches_off_exactly() {
        // Level::Full composes the reduce tail and the center update into
        // one task per iteration; centers and inertia must stay
        // bit-identical to the eager (Level::Off) stream, with strictly
        // fewer tasks submitted.
        let cfg = KMeansConfig {
            k: 2,
            max_iter: 12,
            tol: 1e-7,
            seed: 3,
        };
        let rt_off = Runtime::local(2);
        let x_off = blobs(&rt_off, 60, 6, (16, 6));
        let mut km_off = KMeans::new(cfg.clone());
        km_off.fit_dsarray(&x_off).unwrap();

        let rt_full = Runtime::local(2).with_optimizer(crate::plan::Level::Full);
        let x_full = blobs(&rt_full, 60, 6, (16, 6));
        let mut km_full = KMeans::new(cfg);
        km_full.fit_dsarray(&x_full).unwrap();

        assert_eq!(km_off.n_iter, km_full.n_iter);
        assert_eq!(km_off.inertia, km_full.inertia);
        let ca = km_off.centers.unwrap();
        let cb = km_full.centers.unwrap();
        assert_eq!(ca.max_abs_diff(&cb), 0.0, "centers diverged");

        let m_off = rt_off.metrics();
        let m_full = rt_full.metrics();
        let iters = km_full.n_iter as u64;
        assert_eq!(m_full.tasks_for("kmeans.reduce_update"), iters);
        assert_eq!(m_full.tasks_for("kmeans.update"), 0);
        assert!(
            m_full.total_tasks() < m_off.total_tasks(),
            "full {} !< off {}",
            m_full.total_tasks(),
            m_off.total_tasks()
        );
    }

    #[test]
    fn predict_labels_match_blob_membership() {
        let rt = Runtime::local(2);
        let x = blobs(&rt, 40, 4, (10, 4));
        let mut km = KMeans::new(KMeansConfig {
            k: 2,
            max_iter: 15,
            tol: 1e-6,
            seed: 1,
        });
        km.fit(&x, None).unwrap();
        let labels = km.predict(&x).unwrap().collect().unwrap();
        // All first-half labels equal, all second-half equal, and different.
        let a = labels.get(0, 0);
        let b = labels.get(39, 0);
        assert_ne!(a, b);
        for i in 0..20 {
            assert_eq!(labels.get(i, 0), a, "row {i}");
        }
        for i in 20..40 {
            assert_eq!(labels.get(i, 0), b, "row {i}");
        }
    }

    #[test]
    fn dataset_and_dsarray_paths_agree() {
        let rt = Runtime::local(2);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let m = DenseMatrix::from_fn(48, 5, |i, _| {
            (if i % 2 == 0 { 3.0 } else { -3.0 }) + rng.next_normal() * 0.3
        });
        let x = creation::from_matrix(&rt, &m, (12, 5)).unwrap();
        let ds = crate::dataset::Dataset::from_matrix(&rt, &m, None, 4).unwrap();
        let cfg = KMeansConfig {
            k: 2,
            max_iter: 12,
            tol: 1e-7,
            seed: 2,
        };
        let mut km_a = KMeans::new(cfg.clone());
        km_a.fit_dsarray(&x).unwrap();
        let mut km_d = KMeans::new(cfg);
        km_d.fit_dataset(&ds).unwrap();
        // Same init + same partition boundaries => identical trajectories.
        assert!((km_a.inertia - km_d.inertia).abs() < 1e-2);
        let ca = km_a.centers.unwrap();
        let cd = km_d.centers.unwrap();
        assert!(ca.max_abs_diff(&cd) < 1e-3);
    }

    #[test]
    fn sim_mode_builds_iteration_graph() {
        let sim = Runtime::sim(SimConfig::with_workers(8));
        let x = creation::random(&sim, (1000, 16), (100, 16), 0).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 4,
            max_iter: 3,
            tol: 0.0,
            seed: 0,
        });
        km.fit_dsarray(&x).unwrap();
        let m = sim.metrics();
        // 10 partials per iteration × 3 iterations.
        assert_eq!(m.tasks_for("kmeans.partial"), 30);
        assert_eq!(m.tasks_for("kmeans.update"), 3);
        assert!(m.tasks_for("kmeans.reduce") >= 3);
        let report = sim.run_sim().unwrap();
        assert!(report.makespan_s > 0.0);
        assert!(km.centers.is_none(), "sim mode cannot materialize centers");
    }

    #[test]
    fn fit_and_predict_on_row_slice_views() {
        // Views flow through fit/predict: an unaligned row slice is
        // materialized once at entry instead of copied per iteration.
        let rt = Runtime::local(2);
        let x = blobs(&rt, 60, 6, (16, 6));
        let v = x.slice_rows(1, 59).unwrap();
        assert!(v.is_view());
        let mut km = KMeans::new(KMeansConfig {
            k: 2,
            max_iter: 20,
            tol: 1e-6,
            seed: 3,
        });
        km.fit_dsarray(&v).unwrap();
        let labels = km.predict(&v).unwrap().collect().unwrap();
        assert_eq!(labels.rows(), 58);
        let a = labels.get(0, 0);
        let b = labels.get(57, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn rejects_bad_k() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (4, 2), (2, 2)).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 10,
            ..Default::default()
        });
        assert!(km.fit_dsarray(&x).is_err());
    }
}
