//! StandardScaler: per-feature standardization, the canonical first stage
//! of the pipeline example. Fit computes distributed column statistics;
//! transform standardizes through the **fused elementwise engine**: the
//! `(x − μ) · σ⁻¹` chain is two deferred row-broadcasts that collapse to
//! exactly ONE task per block (and zero intermediate allocations when the
//! input block is exclusively owned) at materialization.
//!
//! This supersedes the per-block PJRT `standardize` artifact dispatch the
//! transform used previously (the fused evaluator does the same single
//! pass natively, composes with further chained ops, and can run in
//! place); `runtime::exec::standardize` remains available for direct
//! artifact calls and is still exercised by the PJRT bench/tests.

use anyhow::{bail, Result};

use crate::dsarray::{creation, DsArray};
use crate::storage::DenseMatrix;

pub struct StandardScaler {
    /// (1, f) feature means after fit.
    pub mean: Option<DenseMatrix>,
    /// (1, f) feature inverse standard deviations after fit.
    pub inv_std: Option<DenseMatrix>,
    pub eps: f32,
}

impl Default for StandardScaler {
    fn default() -> Self {
        Self {
            mean: None,
            inv_std: None,
            eps: 1e-8,
        }
    }
}

impl StandardScaler {
    /// Compute per-feature mean and std from the data (distributed sums +
    /// sums of squares, synchronized at the end).
    pub fn fit(&mut self, x: &DsArray) -> Result<()> {
        let rt = x.runtime();
        if rt.is_sim() {
            bail!("scaler fit requires synchronization (local mode)");
        }
        // Force lazy views once for the two reduction passes.
        let x = x.force()?;
        let x = &x;
        let n = x.rows() as f32;
        let sums = x.sum_axis(0)?.collect()?;
        // `x ** 2` stays deferred: sum_axis fuses it into its own pass via
        // force, so this is one fused task + one reduction per block-line.
        let sumsq = x.pow(2.0)?.sum_axis(0)?.collect()?;
        let f = x.cols();
        let mean = DenseMatrix::from_fn(1, f, |_, j| sums.get(0, j) / n);
        let eps = self.eps;
        let inv_std = DenseMatrix::from_fn(1, f, |_, j| {
            let mu = mean.get(0, j);
            let var = (sumsq.get(0, j) / n - mu * mu).max(0.0);
            1.0 / (var + eps).sqrt()
        });
        self.mean = Some(mean);
        self.inv_std = Some(inv_std);
        Ok(())
    }

    /// Standardize every block: `(x − μ) · σ⁻¹` as one deferred fused
    /// chain — zero tasks now, exactly one task per block when the result
    /// is consumed (and in-place execution when the input is a dead
    /// intermediate). Returns the lazy array; chain further elementwise ops
    /// onto it for free, or `force()` it to materialize once.
    pub fn transform(&self, x: &DsArray) -> Result<DsArray> {
        let (mean, inv) = match (&self.mean, &self.inv_std) {
            (Some(m), Some(s)) => (m.clone(), s.clone()),
            _ => bail!("transform before fit"),
        };
        if mean.cols() != x.cols() {
            bail!("scaler fitted on {} features, got {}", mean.cols(), x.cols());
        }
        let x = x.force()?;
        let rt = x.runtime().clone();
        let bw = x.block_shape().1;
        let mean_arr = creation::from_matrix(&rt, &mean, (1, bw))?;
        let inv_arr = creation::from_matrix(&rt, &inv, (1, bw))?;
        x.sub_row_broadcast(&mean_arr)?.mul_row_broadcast(&inv_arr)
    }

    pub fn fit_transform(&mut self, x: &DsArray) -> Result<DsArray> {
        self.fit(x)?;
        self.transform(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasking::Runtime;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn transformed_columns_are_standard() {
        let rt = Runtime::local(2);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let m = DenseMatrix::from_fn(100, 5, |_, j| {
            rng.next_normal() * (j as f32 + 1.0) + 10.0 * j as f32
        });
        let x = creation::from_matrix(&rt, &m, (32, 2)).unwrap();
        let mut sc = StandardScaler::default();
        let t = sc.fit_transform(&x).unwrap().collect().unwrap();
        for j in 0..5 {
            let col: Vec<f32> = (0..100).map(|i| t.get(i, j)).collect();
            let mean = col.iter().sum::<f32>() / 100.0;
            let var = col.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 100.0;
            assert!(mean.abs() < 1e-3, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "col {j} var {var}");
        }
    }

    #[test]
    fn transform_chain_is_one_fused_task_per_block() {
        // The acceptance criterion on the estimator hot path: the scaler's
        // `(x − μ) · σ⁻¹` chain submits exactly one task per block.
        let rt = Runtime::local(2);
        let m = DenseMatrix::from_fn(64, 6, |i, j| (i * 6 + j) as f32 * 0.1);
        let x = creation::from_matrix(&rt, &m, (16, 3)).unwrap();
        let mut sc = StandardScaler::default();
        sc.fit(&x).unwrap();
        let before = rt.metrics();
        let t = sc.transform(&x).unwrap();
        // Deferred: nothing submitted yet.
        assert!(t.is_deferred());
        assert_eq!(rt.metrics().since(&before).total_tasks(), 0);
        let got = t.collect().unwrap();
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dsarray.ew.fused"), x.n_blocks() as u64);
        assert_eq!(d.total_tasks(), x.n_blocks() as u64);
        assert_eq!(d.tasks_fused, x.n_blocks() as u64); // 2 ops fused to 1
        // Values match the unfused reference computation.
        let mean = sc.mean.as_ref().unwrap();
        let inv = sc.inv_std.as_ref().unwrap();
        let want =
            DenseMatrix::from_fn(64, 6, |i, j| (m.get(i, j) - mean.get(0, j)) * inv.get(0, j));
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn constant_column_does_not_blow_up() {
        let rt = Runtime::local(1);
        let m = DenseMatrix::from_fn(10, 2, |i, j| if j == 0 { 3.0 } else { i as f32 });
        let x = creation::from_matrix(&rt, &m, (5, 2)).unwrap();
        let mut sc = StandardScaler::default();
        let t = sc.fit_transform(&x).unwrap().collect().unwrap();
        for i in 0..10 {
            assert!(t.get(i, 0).abs() < 1.0, "constant col stays bounded");
            assert!(t.get(i, 0).is_finite());
        }
    }

    #[test]
    fn transform_rejects_feature_mismatch_and_unfitted() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (4, 2), (2, 2)).unwrap();
        let sc = StandardScaler::default();
        assert!(sc.transform(&x).is_err());
        let mut sc = StandardScaler::default();
        sc.fit(&x).unwrap();
        let y = creation::zeros(&rt, (4, 3), (2, 3)).unwrap();
        assert!(sc.transform(&y).is_err());
    }
}
