//! StandardScaler: per-feature standardization, the canonical first stage
//! of the pipeline example. Fit computes distributed column statistics;
//! transform standardizes each block through the fused `standardize` PJRT
//! artifact (native fallback when artifacts are absent or blocks exceed the
//! canonical shapes).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dsarray::DsArray;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future};

pub struct StandardScaler {
    /// (1, f) feature means after fit.
    pub mean: Option<DenseMatrix>,
    /// (1, f) feature inverse standard deviations after fit.
    pub inv_std: Option<DenseMatrix>,
    pub eps: f32,
}

impl Default for StandardScaler {
    fn default() -> Self {
        Self {
            mean: None,
            inv_std: None,
            eps: 1e-8,
        }
    }
}

impl StandardScaler {
    /// Compute per-feature mean and std from the data (distributed sums +
    /// sums of squares, synchronized at the end).
    pub fn fit(&mut self, x: &DsArray) -> Result<()> {
        let rt = x.runtime();
        if rt.is_sim() {
            bail!("scaler fit requires synchronization (local mode)");
        }
        // Force lazy views once for the two reduction passes.
        let x = x.force()?;
        let x = &x;
        let n = x.rows() as f32;
        let sums = x.sum_axis(0)?.collect()?;
        let sumsq = x.pow(2.0)?.sum_axis(0)?.collect()?;
        let f = x.cols();
        let mean = DenseMatrix::from_fn(1, f, |_, j| sums.get(0, j) / n);
        let eps = self.eps;
        let inv_std = DenseMatrix::from_fn(1, f, |_, j| {
            let mu = mean.get(0, j);
            let var = (sumsq.get(0, j) / n - mu * mu).max(0.0);
            1.0 / (var + eps).sqrt()
        });
        self.mean = Some(mean);
        self.inv_std = Some(inv_std);
        Ok(())
    }

    /// Standardize every block: `(x - μ) σ⁻¹` (fused PJRT kernel per block).
    pub fn transform(&self, x: &DsArray) -> Result<DsArray> {
        let (mean, inv) = match (&self.mean, &self.inv_std) {
            (Some(m), Some(s)) => (m.clone(), s.clone()),
            _ => bail!("transform before fit"),
        };
        if mean.cols() != x.cols() {
            bail!("scaler fitted on {} features, got {}", mean.cols(), x.cols());
        }
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        let bs1 = x.block_shape().1;
        let mut batch = Vec::with_capacity(x.n_blocks());
        for i in 0..x.grid().0 {
            for j in 0..x.grid().1 {
                let fut = x.block(i, j);
                let c0 = j * bs1;
                let cols = x.block_cols_at(j);
                let mu = mean.slice(0, c0, 1, cols)?;
                let is = inv.slice(0, c0, 1, cols)?;
                let meta = BlockMeta::dense(fut.meta.rows, cols);
                batch.push(BatchTask::new(
                    "scaler.transform",
                    vec![fut],
                    vec![meta],
                    CostHint::flops(2.0 * (meta.rows * meta.cols) as f64)
                        .with_bytes(2.0 * meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let d = ins[0].to_dense()?;
                        // PJRT fused kernel when the block fits an artifact.
                        if d.rows() <= 128 && d.cols() <= 128 {
                            if let Some(svc) = crate::runtime::global() {
                                let out = crate::runtime::exec::standardize(svc, &d, &mu, &is)?;
                                return Ok(vec![Block::Dense(out)]);
                            }
                        }
                        let out = DenseMatrix::from_fn(d.rows(), d.cols(), |r, c| {
                            (d.get(r, c) - mu.get(0, c)) * is.get(0, c)
                        });
                        Ok(vec![Block::Dense(out)])
                    }),
                ));
            }
        }
        let blocks: Vec<Future> = rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        DsArray::from_parts(rt, x.shape(), x.block_shape(), blocks, false)
    }

    pub fn fit_transform(&mut self, x: &DsArray) -> Result<DsArray> {
        self.fit(x)?;
        self.transform(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::tasking::Runtime;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn transformed_columns_are_standard() {
        let rt = Runtime::local(2);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let m = DenseMatrix::from_fn(100, 5, |_, j| {
            rng.next_normal() * (j as f32 + 1.0) + 10.0 * j as f32
        });
        let x = creation::from_matrix(&rt, &m, (32, 2)).unwrap();
        let mut sc = StandardScaler::default();
        let t = sc.fit_transform(&x).unwrap().collect().unwrap();
        for j in 0..5 {
            let col: Vec<f32> = (0..100).map(|i| t.get(i, j)).collect();
            let mean = col.iter().sum::<f32>() / 100.0;
            let var = col.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 100.0;
            assert!(mean.abs() < 1e-3, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "col {j} var {var}");
        }
    }

    #[test]
    fn constant_column_does_not_blow_up() {
        let rt = Runtime::local(1);
        let m = DenseMatrix::from_fn(10, 2, |i, j| if j == 0 { 3.0 } else { i as f32 });
        let x = creation::from_matrix(&rt, &m, (5, 2)).unwrap();
        let mut sc = StandardScaler::default();
        let t = sc.fit_transform(&x).unwrap().collect().unwrap();
        for i in 0..10 {
            assert!(t.get(i, 0).abs() < 1.0, "constant col stays bounded");
            assert!(t.get(i, 0).is_finite());
        }
    }

    #[test]
    fn transform_rejects_feature_mismatch_and_unfitted() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (4, 2), (2, 2)).unwrap();
        let sc = StandardScaler::default();
        assert!(sc.transform(&x).is_err());
        let mut sc = StandardScaler::default();
        sc.fit(&x).unwrap();
        let y = creation::zeros(&rt, (4, 3), (2, 3)).unwrap();
        assert!(sc.transform(&y).is_err());
    }
}
