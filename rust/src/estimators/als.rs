//! Alternating least squares (paper §5.3) — the algorithm that exposes the
//! Dataset structure's weakness.
//!
//! The model factorizes the ratings matrix `R (m×n) ≈ U Vᵀ` with latent
//! dimension `d`, alternating ridge-regression updates:
//!
//! ```text
//!   U ← R V (VᵀV + λI)⁻¹        (needs ROW access to R)
//!   V ← Rᵀ U (UᵀU + λI)⁻¹       (needs COLUMN access to R)
//! ```
//!
//! * **ds-array path**: block columns are directly addressable, so the V
//!   update reads `R`'s block-columns — no transposed copy, no extra memory.
//! * **Dataset path** (baseline): Datasets partition by rows only, so fit
//!   first materializes a transposed copy (`N²+N` tasks, 2× memory) and
//!   runs the V update against it — exactly what dislib's ALS did.
//!
//! The paper's evaluation is about runtime structure, not recommender
//! quality; like the original we use the all-entries least-squares variant
//! (missing entries as zeros), which preserves the cost structure
//! (`O(nnz·d)` products + `O(d³)` solves). Hot matmuls go through the PJRT
//! gemm artifacts when block shapes fit.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dataset::Dataset;
use crate::dsarray::DsArray;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{BatchTask, CostHint, Future, Runtime};
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct AlsConfig {
    /// Latent dimension.
    pub d: usize,
    pub lambda: f32,
    pub max_iter: usize,
    pub seed: u64,
}

impl Default for AlsConfig {
    fn default() -> Self {
        Self {
            d: 32,
            lambda: 0.1,
            max_iter: 5,
            seed: 7,
        }
    }
}

pub struct Als {
    pub cfg: AlsConfig,
    /// Fitted factors (local mode): U (m, d), V (n, d).
    pub u: Option<DenseMatrix>,
    pub v: Option<DenseMatrix>,
}

impl Als {
    pub fn new(cfg: AlsConfig) -> Self {
        Self {
            cfg,
            u: None,
            v: None,
        }
    }

    /// Random (k, d) factor panels aligned to a list of panel heights —
    /// one batch for all panels.
    fn init_factor(rt: &Runtime, heights: &[usize], d: usize, seed: u64) -> Vec<Future> {
        let batch: Vec<BatchTask> = heights
            .iter()
            .enumerate()
            .map(|(i, &h)| {
                let meta = BlockMeta::dense(h, d);
                let s = seed ^ (i as u64) << 17;
                BatchTask::new(
                    "als.init_factor",
                    Vec::new(),
                    vec![meta],
                    CostHint::default().with_bytes(meta.bytes() as f64),
                    Arc::new(move |_| {
                        let mut rng = Xoshiro256::seed_from_u64(s);
                        Ok(vec![Block::Dense(DenseMatrix::from_fn(h, d, |_, _| {
                            rng.next_f32() * 0.1
                        }))])
                    }),
                )
            })
            .collect();
        rt.submit_batch(batch).into_iter().map(|v| v[0]).collect()
    }

    /// Gram of a panel-distributed factor: Σ Fᵢᵀ Fᵢ (+ λI), tree-reduced.
    /// Partials and every tree level go out as one batch each.
    fn factor_gram(rt: &Runtime, panels: &[Future], d: usize, lambda: f32) -> Future {
        let batch: Vec<BatchTask> = panels
            .iter()
            .map(|&p| {
                let flops = 2.0 * p.meta.rows as f64 * (d * d) as f64;
                BatchTask::new(
                    "als.gram_partial",
                    vec![p],
                    vec![BlockMeta::dense(d, d)],
                    CostHint::flops(flops).with_bytes(p.meta.bytes() as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let f = ins[0].to_dense()?;
                        let g = gram_accelerated(&f)?;
                        Ok(vec![Block::Dense(g)])
                    }),
                )
            })
            .collect();
        let mut partials: Vec<Future> =
            rt.submit_batch(batch).into_iter().map(|v| v[0]).collect();
        if rt.planner().fuse_enabled() {
            // Plan layer on: the last reduce level and the λI ridge run as
            // one composed `als.gram_reduce_ridge` task. The axpy fold and
            // the diagonal add are the same operations the eager pair
            // performs, in the same order, so grams stay bit-identical.
            while partials.len() > 8 {
                partials = gram_reduce_level(rt, partials, d);
            }
            let n = partials.len();
            let task = BatchTask::new(
                "als.gram_reduce_ridge",
                partials,
                vec![BlockMeta::dense(d, d)],
                CostHint::flops((n * d * d + d) as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let mut g = ins[0].to_dense()?;
                    for b in &ins[1..] {
                        g.axpy(1.0, &b.to_dense()?)?;
                    }
                    for i in 0..g.rows() {
                        let v = g.get(i, i) + lambda;
                        g.set(i, i, v);
                    }
                    Ok(vec![Block::Dense(g)])
                }),
            )
            .with_fused_ops(2);
            return rt.submit_batch(vec![task]).remove(0)[0];
        }
        // Tree-reduce, then add λI in the final task.
        while partials.len() > 1 {
            partials = gram_reduce_level(rt, partials, d);
        }
        rt.submit(
            "als.gram_ridge",
            &[partials[0]],
            vec![BlockMeta::dense(d, d)],
            CostHint::flops(d as f64),
            Arc::new(move |ins: &[Arc<Block>]| {
                let mut g = ins[0].to_dense()?;
                for i in 0..g.rows() {
                    let v = g.get(i, i) + lambda;
                    g.set(i, i, v);
                }
                Ok(vec![Block::Dense(g)])
            }),
        )[0]
    }

    /// Build one factor-panel update task: `F_line = (Σ_b R_b @ P_b) G⁻¹`
    /// where the R blocks and opposite panels come in as collections.
    /// `transpose_r` selects `R_bᵀ` (the V update reading block-columns).
    /// Returned as a [`BatchTask`] so callers batch a whole update phase.
    fn update_line_task(
        r_blocks: &[Future],
        opposite: &[Future],
        gram: Future,
        rows_out: usize,
        d: usize,
        transpose_r: bool,
        name: &'static str,
    ) -> BatchTask {
        let nb = r_blocks.len();
        let mut reads = r_blocks.to_vec();
        reads.extend_from_slice(opposite);
        reads.push(gram);
        let nnz: f64 = r_blocks.iter().map(|b| b.meta.nnz as f64).sum();
        let flops = 2.0 * nnz * d as f64 + rows_out as f64 * (d * d) as f64;
        let bytes: f64 = reads.iter().map(|b| b.meta.bytes() as f64).sum();
        BatchTask::new(
            name,
            reads,
            vec![BlockMeta::dense(rows_out, d)],
            CostHint::flops(flops).with_bytes(bytes),
            Arc::new(move |ins: &[Arc<Block>]| {
                let r_blocks = &ins[..nb];
                let panels = &ins[nb..ins.len() - 1];
                let g = ins[ins.len() - 1].to_dense()?;
                let mut s = DenseMatrix::zeros(rows_out, g.rows());
                let product = |rb: &Block, p: &DenseMatrix| -> Result<DenseMatrix> {
                    match (rb, transpose_r) {
                        (Block::Csr(c), false) => c.matmul_dense(p),
                        (Block::Csr(c), true) => c.transpose().matmul_dense(p),
                        (b, false) => matmul_accelerated(&b.to_dense()?, p),
                        (b, true) => tn_matmul_accelerated(&b.to_dense()?, p),
                    }
                };
                if r_blocks.len() == panels.len() {
                    // Aligned path (ds-array): R block b pairs with panel b.
                    for (rb, pb) in r_blocks.iter().zip(panels) {
                        s.axpy(1.0, &product(rb, &pb.to_dense()?)?)?;
                    }
                } else {
                    // Whole-operand path (Dataset subsets): stack the
                    // opposite factor into one (n, d) matrix first.
                    let dense: Vec<DenseMatrix> = panels
                        .iter()
                        .map(|b| b.to_dense())
                        .collect::<Result<_>>()?;
                    let refs: Vec<&DenseMatrix> = dense.iter().collect();
                    let full = DenseMatrix::vstack(&refs)?;
                    for rb in r_blocks {
                        s.axpy(1.0, &product(rb, &full)?)?;
                    }
                }
                // F = S G⁻¹  ⇔  Fᵀ = G⁻¹ Sᵀ (G is SPD after ridge).
                let ft = g.solve_spd(&s.transpose())?;
                Ok(vec![Block::Dense(ft.transpose())])
            }),
        )
    }

    /// Fit on a ds-array: row updates read block-rows, column updates read
    /// block-columns **directly** — zero transpose tasks.
    pub fn fit_dsarray(&mut self, r: &DsArray) -> Result<()> {
        let r = r.force()?;
        let r = &r;
        let rt = r.runtime().clone();
        let d = self.cfg.d;
        if d == 0 {
            bail!("latent dimension must be positive");
        }
        let (gr, gc) = r.grid();
        let u_heights: Vec<usize> = (0..gr).map(|i| r.block_rows_at(i)).collect();
        let v_heights: Vec<usize> = (0..gc).map(|j| r.block_cols_at(j)).collect();
        let mut u = Self::init_factor(&rt, &u_heights, d, self.cfg.seed);
        let mut v = Self::init_factor(&rt, &v_heights, d, self.cfg.seed ^ 0xABCD);

        for _ in 0..self.cfg.max_iter {
            // U ← R V Gv⁻¹ : one task per block-row, one batch per phase.
            let gv = Self::factor_gram(&rt, &v, d, self.cfg.lambda);
            let batch: Vec<BatchTask> = (0..gr)
                .map(|i| {
                    Self::update_line_task(
                        &r.block_row(i),
                        &v,
                        gv,
                        u_heights[i],
                        d,
                        false,
                        "als.update_u",
                    )
                })
                .collect();
            u = rt.submit_batch(batch).into_iter().map(|o| o[0]).collect();
            // V ← Rᵀ U Gu⁻¹ : one task per block-column — DIRECT access.
            let gu = Self::factor_gram(&rt, &u, d, self.cfg.lambda);
            let batch: Vec<BatchTask> = (0..gc)
                .map(|j| {
                    Self::update_line_task(
                        &r.block_col(j),
                        &u,
                        gu,
                        v_heights[j],
                        d,
                        true,
                        "als.update_v",
                    )
                })
                .collect();
            v = rt.submit_batch(batch).into_iter().map(|o| o[0]).collect();
        }
        if !rt.is_sim() {
            self.u = Some(collect_panels(&rt, &u)?);
            self.v = Some(collect_panels(&rt, &v)?);
        }
        Ok(())
    }

    /// Fit on a Dataset (baseline): materializes the transposed copy first
    /// (`N²+N` tasks + 2× memory), then runs both updates as row accesses.
    pub fn fit_dataset(&mut self, ds: &Dataset) -> Result<()> {
        let rt = ds.runtime().clone();
        let d = self.cfg.d;
        // THE baseline cost: transpose the samples once at fit start.
        let rt_ds = ds.transpose()?;

        let u_heights: Vec<usize> = (0..ds.n_subsets()).map(|i| ds.subset_size(i)).collect();
        let v_heights: Vec<usize> = (0..rt_ds.n_subsets())
            .map(|i| rt_ds.subset_size(i))
            .collect();
        let mut u = Self::init_factor(&rt, &u_heights, d, self.cfg.seed);
        let mut v = Self::init_factor(&rt, &v_heights, d, self.cfg.seed ^ 0xABCD);

        // V panels are aligned to Rᵀ subsets (row panels of the transposed
        // copy) — but the U update needs V as a single (n, d) operand per
        // task; we pass all V panels as a collection, as the ds-array path
        // does. Likewise for U in the V update.
        for _ in 0..self.cfg.max_iter {
            let gv = Self::factor_gram(&rt, &v, d, self.cfg.lambda);
            let batch: Vec<BatchTask> = (0..ds.n_subsets())
                .map(|i| {
                    Self::update_line_task(
                        &[ds.subset(i).samples],
                        &v,
                        gv,
                        u_heights[i],
                        d,
                        false,
                        "als_dataset.update_u",
                    )
                })
                .collect();
            u = rt.submit_batch(batch).into_iter().map(|o| o[0]).collect();
            let gu = Self::factor_gram(&rt, &u, d, self.cfg.lambda);
            let batch: Vec<BatchTask> = (0..rt_ds.n_subsets())
                .map(|j| {
                    Self::update_line_task(
                        &[rt_ds.subset(j).samples],
                        &u,
                        gu,
                        v_heights[j],
                        d,
                        false, // rows of the TRANSPOSED copy
                        "als_dataset.update_v",
                    )
                })
                .collect();
            v = rt.submit_batch(batch).into_iter().map(|o| o[0]).collect();
        }
        if !rt.is_sim() {
            self.u = Some(collect_panels(&rt, &u)?);
            self.v = Some(collect_panels(&rt, &v)?);
        }
        Ok(())
    }

    /// Predicted rating for entry (i, j) — local mode, after fit.
    pub fn predict_one(&self, i: usize, j: usize) -> Result<f32> {
        let (u, v) = match (&self.u, &self.v) {
            (Some(u), Some(v)) => (u, v),
            _ => bail!("predict before fit"),
        };
        if i >= u.rows() || j >= v.rows() {
            bail!("index ({i},{j}) out of bounds");
        }
        Ok(u.row(i).iter().zip(v.row(j)).map(|(&a, &b)| a * b).sum())
    }

    /// Full reconstruction `U Vᵀ` (small cases / tests).
    pub fn reconstruct(&self) -> Result<DenseMatrix> {
        let (u, v) = match (&self.u, &self.v) {
            (Some(u), Some(v)) => (u, v),
            _ => bail!("reconstruct before fit"),
        };
        u.matmul(&v.transpose())
    }

    /// Root-mean-square error against a dense reference.
    pub fn rmse(&self, r: &DenseMatrix) -> Result<f64> {
        let rec = self.reconstruct()?;
        if (rec.rows(), rec.cols()) != (r.rows(), r.cols()) {
            bail!("shape mismatch in rmse");
        }
        let sq: f64 = rec
            .data()
            .iter()
            .zip(r.data())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        Ok((sq / r.data().len() as f64).sqrt())
    }
}

/// One tree level of the gram reduction: merge 8-wide chunks with
/// `als.gram_reduce` tasks, pass lone stragglers through.
fn gram_reduce_level(rt: &Runtime, partials: Vec<Future>, d: usize) -> Vec<Future> {
    let mut next: Vec<Option<Future>> = Vec::with_capacity(partials.len().div_ceil(8));
    let mut batch = Vec::new();
    for chunk in partials.chunks(8) {
        if chunk.len() == 1 {
            next.push(Some(chunk[0]));
            continue;
        }
        next.push(None);
        batch.push(BatchTask::new(
            "als.gram_reduce",
            chunk.to_vec(),
            vec![BlockMeta::dense(d, d)],
            CostHint::flops((chunk.len() * d * d) as f64),
            Arc::new(|ins: &[Arc<Block>]| {
                let mut acc = ins[0].to_dense()?;
                for b in &ins[1..] {
                    acc.axpy(1.0, &b.to_dense()?)?;
                }
                Ok(vec![Block::Dense(acc)])
            }),
        ));
    }
    let mut outs = rt.submit_batch(batch).into_iter();
    next.into_iter()
        .map(|slot| slot.unwrap_or_else(|| outs.next().expect("batch output per chunk")[0]))
        .collect()
}

/// FᵀF through the PJRT gemm_tn artifact when it fits, tiled over row
/// chunks; native otherwise.
fn gram_accelerated(f: &DenseMatrix) -> Result<DenseMatrix> {
    let d = f.cols();
    let mut g = DenseMatrix::zeros(d, d);
    if d <= 128 {
        if let Some(svc) = crate::runtime::global() {
            let mut r0 = 0;
            while r0 < f.rows() {
                let rows = (f.rows() - r0).min(128);
                let chunk = f.slice(r0, 0, rows, d)?;
                g = crate::runtime::exec::gemm_tn_acc(svc, &chunk, &chunk, &g)?;
                r0 += rows;
            }
            return Ok(g);
        }
    }
    let ft = f.transpose();
    g.gemm_acc(&ft, f)?;
    Ok(g)
}

fn matmul_accelerated(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    if a.cols().max(b.cols()) <= 128 && a.rows() <= 128 {
        if let Some(svc) = crate::runtime::global() {
            let c = DenseMatrix::zeros(a.rows(), b.cols());
            return crate::runtime::exec::gemm_acc(svc, a, b, &c);
        }
    }
    a.matmul(b)
}

fn tn_matmul_accelerated(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    if a.cols().max(b.cols()) <= 128 && a.rows() <= 128 {
        if let Some(svc) = crate::runtime::global() {
            let c = DenseMatrix::zeros(a.cols(), b.cols());
            return crate::runtime::exec::gemm_tn_acc(svc, a, b, &c);
        }
    }
    a.transpose().matmul(b)
}

fn collect_panels(rt: &Runtime, panels: &[Future]) -> Result<DenseMatrix> {
    let mut parts = Vec::with_capacity(panels.len());
    for &p in panels {
        parts.push(rt.wait(p)?.to_dense()?);
    }
    let refs: Vec<&DenseMatrix> = parts.iter().collect();
    DenseMatrix::vstack(&refs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsarray::creation;
    use crate::tasking::SimConfig;

    /// Low-rank ground truth R = U* V*ᵀ.
    fn low_rank(m: usize, n: usize, d: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let u = DenseMatrix::from_fn(m, d, |_, _| rng.next_normal() * 0.5);
        let v = DenseMatrix::from_fn(n, d, |_, _| rng.next_normal() * 0.5);
        u.matmul(&v.transpose()).unwrap()
    }

    #[test]
    fn recovers_low_rank_matrix_dsarray() {
        let rt = Runtime::local(2);
        let r = low_rank(24, 18, 3, 1);
        let x = creation::from_matrix(&rt, &r, (8, 6)).unwrap();
        let mut als = Als::new(AlsConfig {
            d: 4,
            lambda: 0.01,
            max_iter: 30,
            seed: 2,
        });
        als.fit_dsarray(&x).unwrap();
        let rmse = als.rmse(&r).unwrap();
        assert!(rmse < 0.05, "rmse {rmse}");
        assert!((als.predict_one(3, 5).unwrap() - r.get(3, 5)).abs() < 0.2);
    }

    #[test]
    fn dsarray_path_never_transposes() {
        let rt = Runtime::local(2);
        let r = low_rank(16, 12, 2, 3);
        let x = creation::from_matrix(&rt, &r, (4, 4)).unwrap();
        let mut als = Als::new(AlsConfig {
            d: 3,
            lambda: 0.05,
            max_iter: 2,
            seed: 1,
        });
        als.fit_dsarray(&x).unwrap();
        let m = rt.metrics();
        assert_eq!(m.tasks_with_prefix("dsarray.transpose"), 0);
        assert_eq!(m.tasks_with_prefix("dataset.transpose"), 0);
        assert_eq!(m.tasks_for("als.update_u"), 8); // 4 block rows × 2 iters
        assert_eq!(m.tasks_for("als.update_v"), 6); // 3 block cols × 2 iters
    }

    #[test]
    fn full_optimizer_composes_gram_ridge_and_matches_off_exactly() {
        // Level::Full composes the final gram-reduce level with the λI
        // ridge: two fewer tasks per iteration (one per gram), factors
        // bit-identical to the eager stream.
        let cfg = AlsConfig {
            d: 3,
            lambda: 0.02,
            max_iter: 4,
            seed: 9,
        };
        let r = low_rank(20, 16, 2, 5);

        let rt_off = Runtime::local(2);
        let x_off = creation::from_matrix(&rt_off, &r, (5, 4)).unwrap();
        let mut a = Als::new(cfg.clone());
        a.fit_dsarray(&x_off).unwrap();

        let rt_full = Runtime::local(2).with_optimizer(crate::plan::Level::Full);
        let x_full = creation::from_matrix(&rt_full, &r, (5, 4)).unwrap();
        let mut b = Als::new(cfg);
        b.fit_dsarray(&x_full).unwrap();

        let (ua, va) = (a.u.unwrap(), a.v.unwrap());
        let (ub, vb) = (b.u.unwrap(), b.v.unwrap());
        assert_eq!(ua.max_abs_diff(&ub), 0.0, "U diverged");
        assert_eq!(va.max_abs_diff(&vb), 0.0, "V diverged");

        let m_off = rt_off.metrics();
        let m_full = rt_full.metrics();
        // One composed task per gram, two grams (gv, gu) per iteration.
        assert_eq!(m_full.tasks_for("als.gram_reduce_ridge"), 8);
        assert_eq!(m_full.tasks_for("als.gram_ridge"), 0);
        assert!(
            m_full.total_tasks() < m_off.total_tasks(),
            "full {} !< off {}",
            m_full.total_tasks(),
            m_off.total_tasks()
        );
    }

    #[test]
    fn dataset_path_transposes_once_and_agrees() {
        let rt = Runtime::local(2);
        let r = low_rank(20, 16, 2, 5);
        let x = creation::from_matrix(&rt, &r, (5, 4)).unwrap();
        let ds = Dataset::from_matrix(&rt, &r, None, 4).unwrap();
        let cfg = AlsConfig {
            d: 3,
            lambda: 0.02,
            max_iter: 20,
            seed: 9,
        };
        let mut a = Als::new(cfg.clone());
        a.fit_dsarray(&x).unwrap();
        let mut b = Als::new(cfg);
        b.fit_dataset(&ds).unwrap();
        // The baseline pays the transpose...
        let m = rt.metrics();
        assert_eq!(m.tasks_for("dataset.transpose.split"), 16); // N²
        assert_eq!(m.tasks_for("dataset.transpose.merge"), 4); // N
        // ...but both converge to an equivalent factorization.
        let ra = a.rmse(&r).unwrap();
        let rb = b.rmse(&r).unwrap();
        assert!(ra < 0.05 && rb < 0.05, "rmse {ra} vs {rb}");
    }

    #[test]
    fn sparse_ratings_fit() {
        let rt = Runtime::local(2);
        // Sparse 0/observed low-rank-ish matrix.
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut trips = Vec::new();
        for _ in 0..120 {
            trips.push((
                rng.next_below(20) as usize,
                rng.next_below(15) as usize,
                1.0 + rng.next_f32() * 4.0,
            ));
        }
        let csr = crate::storage::CsrMatrix::from_triplets(20, 15, &trips).unwrap();
        let x = creation::from_csr(&rt, &csr, (5, 5)).unwrap();
        let mut als = Als::new(AlsConfig {
            d: 4,
            lambda: 0.1,
            max_iter: 10,
            seed: 4,
        });
        als.fit_dsarray(&x).unwrap();
        // Reconstruction should correlate with the data: mean prediction on
        // observed cells far above mean on empty cells.
        let rec = als.reconstruct().unwrap();
        let dense = csr.to_dense();
        let (mut on, mut non, mut off, mut noff) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..20 {
            for j in 0..15 {
                if dense.get(i, j) != 0.0 {
                    on += rec.get(i, j) as f64;
                    non += 1;
                } else {
                    off += rec.get(i, j) as f64;
                    noff += 1;
                }
            }
        }
        assert!(on / non as f64 > 2.0 * (off / noff as f64).abs().max(0.05));
    }

    #[test]
    fn sim_mode_graph_shapes() {
        let sim = Runtime::sim(SimConfig::with_workers(8));
        let x = creation::random_sparse(&sim, (400, 300), (100, 100), 0.05, 0).unwrap();
        let mut als = Als::new(AlsConfig {
            d: 8,
            lambda: 0.1,
            max_iter: 2,
            seed: 0,
        });
        als.fit_dsarray(&x).unwrap();
        let m = sim.metrics();
        assert_eq!(m.tasks_for("als.update_u"), 8); // 4 rows × 2 iters
        assert_eq!(m.tasks_for("als.update_v"), 6); // 3 cols × 2 iters
        let report = sim.run_sim().unwrap();
        assert!(report.tasks_executed as u64 == m.total_tasks());
    }
}
