//! Estimators (paper §3.2.2 / §4.3): the scikit-learn-style interface on
//! top of ds-arrays — `fit(x, y)`, `predict(x)`, `score(x, y)` — which the
//! ds-array design makes possible (Datasets forced `fit(dataset)` and
//! label-field abuse, §4.1).
//!
//! K-means and ALS are the paper's evaluation models and are implemented on
//! **both** structures (the Dataset path reproduces the baseline's
//! inefficiencies on purpose). Linear regression, PCA and the
//! StandardScaler are the "natural extensions" §6 motivates.

pub mod als;
pub mod gnb;
pub mod kmeans;
pub mod knn;
pub mod linreg;
pub mod pca;
pub mod scaler;

use anyhow::Result;

use crate::dsarray::DsArray;

/// Anything that learns from data (paper §3.2). `x` rows are samples.
pub trait Estimator {
    /// Learn parameters from samples `x` (and labels `y` when supervised).
    fn fit(&mut self, x: &DsArray, y: Option<&DsArray>) -> Result<()>;

    /// Per-sample predictions as a new rows×1 ds-array — returning a fresh
    /// distributed array instead of mutating the input (the usability fix
    /// over Datasets, §4.1).
    fn predict(&self, x: &DsArray) -> Result<DsArray>;

    /// Model quality on (x, y); higher is better.
    fn score(&self, x: &DsArray, y: &DsArray) -> Result<f64>;
}

pub use als::Als;
pub use gnb::GaussianNb;
pub use kmeans::KMeans;
pub use knn::KnnClassifier;
pub use linreg::LinearRegression;
pub use pca::Pca;
pub use scaler::StandardScaler;
