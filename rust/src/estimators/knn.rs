//! k-nearest-neighbors classifier — a dislib estimator family rebuilt on
//! ds-arrays. Training data stays distributed; prediction streams query
//! block-rows against every training block-row, merging per-block top-k
//! candidate lists (one task per (query row-block, train row-block) pair +
//! a merge per query block). The distance hot spot runs through the
//! pairwise Pallas artifact when shapes fit.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dsarray::DsArray;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::{CostHint, Future};

use super::Estimator;

pub struct KnnClassifier {
    pub k: usize,
    /// Training samples/labels (kept as distributed handles).
    train_x: Option<DsArray>,
    train_y: Option<DsArray>,
}

impl KnnClassifier {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            train_x: None,
            train_y: None,
        }
    }
}

/// Per-block candidate table: (k_best distances, labels) as a (2k, q) dense
/// block — row 0..k distances, row k..2k labels, one column per query row.
fn candidates_block(
    queries: &DenseMatrix,
    train: &DenseMatrix,
    labels: &DenseMatrix,
    k: usize,
) -> Result<DenseMatrix> {
    let d2 = pairwise(queries, train)?;
    let q = queries.rows();
    let mut out = DenseMatrix::full(2 * k, q, f32::INFINITY);
    for qi in 0..q {
        // Partial selection of the k smallest distances.
        let row = d2.row(qi);
        let mut idx: Vec<usize> = (0..row.len()).collect();
        let kk = k.min(row.len());
        idx.select_nth_unstable_by(kk - 1, |&a, &b| {
            row[a].partial_cmp(&row[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        for (slot, &t) in idx[..kk].iter().enumerate() {
            out.set(slot, qi, row[t]);
            out.set(k + slot, qi, labels.get(t, 0));
        }
    }
    Ok(out)
}

fn pairwise(x: &DenseMatrix, y: &DenseMatrix) -> Result<DenseMatrix> {
    let fits = x.rows().max(x.cols()).max(y.rows()) <= 128;
    if fits {
        if let Some(svc) = crate::runtime::global() {
            return crate::runtime::exec::pairwise_dist2(svc, x, y);
        }
    }
    // Native fallback: the kernel-layer distance micro-kernel (SIMD when
    // available, scalar otherwise — bit-identical either way).
    x.pairwise_dist2(y)
}

impl Estimator for KnnClassifier {
    /// "Fitting" records the training set (lazy learner). Lazy views — e.g.
    /// the result of `train_test_split` — are materialized here, so
    /// prediction reads canonical block grids.
    fn fit(&mut self, x: &DsArray, y: Option<&DsArray>) -> Result<()> {
        let y = y.ok_or_else(|| anyhow::anyhow!("knn needs labels"))?;
        if y.shape() != (x.rows(), 1) || y.block_shape().0 != x.block_shape().0 {
            bail!("labels must be {}x1 with matching row blocking", x.rows());
        }
        if self.k == 0 || self.k > x.rows() {
            bail!("k={} invalid for {} training rows", self.k, x.rows());
        }
        self.train_x = Some(x.force()?);
        self.train_y = Some(y.force()?);
        Ok(())
    }

    /// Majority label of the k nearest training samples per query row.
    fn predict(&self, x: &DsArray) -> Result<DsArray> {
        let (tx, ty) = match (&self.train_x, &self.train_y) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("predict before fit"),
        };
        if x.cols() != tx.cols() {
            bail!("query has {} features, training {}", x.cols(), tx.cols());
        }
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        let k = self.k;
        let q_gc = x.grid().1;
        let t_gc = tx.grid().1;
        let mut out_blocks = Vec::with_capacity(x.grid().0);
        for qi in 0..x.grid().0 {
            let q_rows = x.block_rows_at(qi);
            // One candidate task per training block-row.
            let mut cands: Vec<Future> = Vec::with_capacity(tx.grid().0);
            for ti in 0..tx.grid().0 {
                let mut reads = x.block_row(qi);
                reads.extend(tx.block_row(ti));
                reads.push(ty.block(ti, 0));
                let meta = BlockMeta::dense(2 * k, q_rows);
                let flops = 3.0 * q_rows as f64 * tx.block_rows_at(ti) as f64 * x.cols() as f64;
                let out = rt.submit(
                    "knn.candidates",
                    &reads,
                    vec![meta],
                    CostHint::flops(flops),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let qs: Vec<DenseMatrix> = ins[..q_gc]
                            .iter()
                            .map(|b| b.to_dense())
                            .collect::<Result<_>>()?;
                        let ts: Vec<DenseMatrix> = ins[q_gc..q_gc + t_gc]
                            .iter()
                            .map(|b| b.to_dense())
                            .collect::<Result<_>>()?;
                        let labels = ins[q_gc + t_gc].to_dense()?;
                        let qrefs: Vec<&DenseMatrix> = qs.iter().collect();
                        let trefs: Vec<&DenseMatrix> = ts.iter().collect();
                        let queries = DenseMatrix::hstack(&qrefs)?;
                        let train = DenseMatrix::hstack(&trefs)?;
                        Ok(vec![Block::Dense(candidates_block(
                            &queries, &train, &labels, k,
                        )?)])
                    }),
                );
                cands.push(out[0]);
            }
            // Merge candidate tables and vote.
            let out = rt.submit(
                "knn.vote",
                &cands,
                vec![BlockMeta::dense(q_rows, 1)],
                CostHint::flops((q_rows * k * tx.grid().0) as f64),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let tables: Vec<DenseMatrix> =
                        ins.iter().map(|b| b.to_dense()).collect::<Result<_>>()?;
                    let q = tables[0].cols();
                    let mut labels_out = DenseMatrix::zeros(q, 1);
                    for qi in 0..q {
                        // Gather all candidates for this query across tables.
                        let mut pool: Vec<(f32, f32)> = Vec::with_capacity(k * tables.len());
                        for t in &tables {
                            for slot in 0..k {
                                let d = t.get(slot, qi);
                                if d.is_finite() {
                                    pool.push((d, t.get(k + slot, qi)));
                                }
                            }
                        }
                        pool.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
                        pool.truncate(k);
                        // Majority vote (ties: smallest label).
                        let mut counts: Vec<(f32, usize)> = Vec::new();
                        for &(_, l) in &pool {
                            match counts.iter_mut().find(|(cl, _)| *cl == l) {
                                Some((_, c)) => *c += 1,
                                None => counts.push((l, 1)),
                            }
                        }
                        counts.sort_by(|a, b| b.1.cmp(&a.1).then(
                            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal),
                        ));
                        labels_out.set(qi, 0, counts.first().map(|&(l, _)| l).unwrap_or(0.0));
                    }
                    Ok(vec![Block::Dense(labels_out)])
                }),
            );
            out_blocks.push(out[0]);
        }
        DsArray::from_parts(rt, (x.rows(), 1), (x.block_shape().0, 1), out_blocks, false)
    }

    /// Classification accuracy.
    fn score(&self, x: &DsArray, y: &DsArray) -> Result<f64> {
        let pred = self.predict(x)?.collect()?;
        let truth = y.collect()?;
        let hits = pred
            .data()
            .iter()
            .zip(truth.data())
            .filter(|(p, t)| p == t)
            .count();
        Ok(hits as f64 / truth.rows() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::blobs;
    use crate::dsarray::creation;
    use crate::tasking::Runtime;

    fn labeled(rt: &Runtime, n: usize, f: usize, k: usize) -> (DsArray, DsArray, Vec<usize>) {
        let (data, truth) = blobs(n, f, k, 0.5, 7);
        let x = creation::from_matrix(rt, &data, (16, f)).unwrap();
        let y_m = DenseMatrix::from_fn(n, 1, |i, _| truth[i] as f32);
        let y = creation::from_matrix(rt, &y_m, (16, 1)).unwrap();
        (x, y, truth)
    }

    #[test]
    fn classifies_blobs_perfectly() {
        let rt = Runtime::local(2);
        let (x, y, _) = labeled(&rt, 96, 8, 3);
        let mut knn = KnnClassifier::new(5);
        knn.fit(&x, Some(&y)).unwrap();
        let acc = knn.score(&x, &y).unwrap();
        assert!(acc > 0.99, "accuracy {acc}");
    }

    #[test]
    fn held_out_queries() {
        let rt = Runtime::local(2);
        let (x, y, _) = labeled(&rt, 90, 6, 3);
        let mut knn = KnnClassifier::new(3);
        knn.fit(&x, Some(&y)).unwrap();
        // Fresh points from the same blobs.
        let (qdata, qtruth) = blobs(30, 6, 3, 0.5, 99);
        let q = creation::from_matrix(&rt, &qdata, (16, 6)).unwrap();
        let pred = knn.predict(&q).unwrap().collect().unwrap();
        let hits = (0..30).filter(|&i| pred.get(i, 0) as usize == qtruth[i]).count();
        assert!(hits >= 28, "hits {hits}/30");
    }

    #[test]
    fn k1_reproduces_training_labels() {
        let rt = Runtime::local(2);
        let (x, y, truth) = labeled(&rt, 48, 4, 2);
        let mut knn = KnnClassifier::new(1);
        knn.fit(&x, Some(&y)).unwrap();
        let pred = knn.predict(&x).unwrap().collect().unwrap();
        for (i, &t) in truth.iter().enumerate() {
            assert_eq!(pred.get(i, 0) as usize, t, "row {i}");
        }
    }

    #[test]
    fn fit_on_train_test_split_views() {
        // The estimator-facing view scenario: split rows into lazy views,
        // fit on the train view, score on the held-out view — data is only
        // copied when fit/predict force the views.
        let rt = Runtime::local(2);
        let (x, y, _) = labeled(&rt, 96, 6, 3);
        let (train_x, test_x) = x.train_test_split(0.25, 11).unwrap();
        let (train_y, test_y) = y.train_test_split(0.25, 11).unwrap();
        assert!(train_x.is_view() && test_x.is_view());
        let mut knn = KnnClassifier::new(5);
        knn.fit(&train_x, Some(&train_y)).unwrap();
        let acc = knn.score(&test_x, &test_y).unwrap();
        assert!(acc > 0.95, "held-out accuracy {acc}");
    }

    #[test]
    fn validation_errors() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (8, 2), (4, 2)).unwrap();
        let mut knn = KnnClassifier::new(3);
        assert!(knn.fit(&x, None).is_err());
        let y_bad = creation::zeros(&rt, (8, 1), (2, 1)).unwrap();
        assert!(knn.fit(&x, Some(&y_bad)).is_err());
        let mut knn0 = KnnClassifier::new(0);
        let y = creation::zeros(&rt, (8, 1), (4, 1)).unwrap();
        assert!(knn0.fit(&x, Some(&y)).is_err());
        assert!(KnnClassifier::new(2).predict(&x).is_err());
    }
}
