//! Gaussian Naive Bayes — per-class feature moments computed distributed
//! (one stats task per (block-row, class) pass + a reduction), prediction
//! per block-row. A natural fit for ds-arrays: the fit is one masked
//! column-stats sweep per class, the same primitive the scaler uses.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dsarray::DsArray;
use crate::storage::{Block, BlockMeta, DenseMatrix};
use crate::tasking::CostHint;

use super::Estimator;

pub struct GaussianNb {
    /// Class labels seen at fit (sorted).
    pub classes: Vec<f32>,
    /// Per class: (1, f) means.
    pub means: Vec<DenseMatrix>,
    /// Per class: (1, f) variances.
    pub vars: Vec<DenseMatrix>,
    /// Per class: prior probability.
    pub priors: Vec<f64>,
    pub var_smoothing: f32,
}

impl Default for GaussianNb {
    fn default() -> Self {
        Self {
            classes: Vec::new(),
            means: Vec::new(),
            vars: Vec::new(),
            priors: Vec::new(),
            var_smoothing: 1e-6,
        }
    }
}

impl GaussianNb {
    fn log_likelihood(&self, row: &[f32], class_idx: usize) -> f64 {
        let mean = &self.means[class_idx];
        let var = &self.vars[class_idx];
        let mut ll = self.priors[class_idx].ln();
        for (j, &x) in row.iter().enumerate() {
            let v = (var.get(0, j) + self.var_smoothing) as f64;
            let d = (x - mean.get(0, j)) as f64;
            ll += -0.5 * ((2.0 * std::f64::consts::PI * v).ln() + d * d / v);
        }
        ll
    }
}

impl Estimator for GaussianNb {
    fn fit(&mut self, x: &DsArray, y: Option<&DsArray>) -> Result<()> {
        let y = y.ok_or_else(|| anyhow::anyhow!("gaussian nb needs labels"))?;
        if y.shape() != (x.rows(), 1) || y.block_shape().0 != x.block_shape().0 {
            bail!("labels must be {}x1 with matching row blocking", x.rows());
        }
        let rt = x.runtime().clone();
        if rt.is_sim() {
            bail!("gnb fit requires synchronization (local mode)");
        }
        let x = x.force()?;
        let x = &x;
        let y = y.force()?;
        let y = &y;
        let f = x.cols();
        let gc = x.grid().1;

        // Discover classes (synchronizes labels — small column).
        let labels = y.collect()?;
        let mut classes: Vec<f32> = labels.data().to_vec();
        classes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        classes.dedup();
        if classes.len() < 2 {
            bail!("need at least 2 classes, got {}", classes.len());
        }

        // Per (block-row, class): masked sums/sumsq/count tasks; reduce on
        // the master (small 1×f partials).
        let mut means = Vec::with_capacity(classes.len());
        let mut vars = Vec::with_capacity(classes.len());
        let mut priors = Vec::with_capacity(classes.len());
        for &cls in &classes {
            let mut partials = Vec::with_capacity(x.grid().0);
            for i in 0..x.grid().0 {
                let mut reads = x.block_row(i);
                reads.push(y.block(i, 0));
                let metas = vec![
                    BlockMeta::dense(1, f),
                    BlockMeta::dense(1, f),
                    BlockMeta::dense(1, 1),
                ];
                let rows = x.block_rows_at(i);
                let out = rt.submit(
                    "gnb.class_stats",
                    &reads,
                    metas,
                    CostHint::flops(3.0 * (rows * f) as f64),
                    Arc::new(move |ins: &[Arc<Block>]| {
                        let dense: Vec<DenseMatrix> = ins[..gc]
                            .iter()
                            .map(|b| b.to_dense())
                            .collect::<Result<_>>()?;
                        let refs: Vec<&DenseMatrix> = dense.iter().collect();
                        let panel = DenseMatrix::hstack(&refs)?;
                        let lab = ins[gc].to_dense()?;
                        let mut sums = DenseMatrix::zeros(1, panel.cols());
                        let mut sq = DenseMatrix::zeros(1, panel.cols());
                        let mut count = 0.0f32;
                        for r in 0..panel.rows() {
                            if lab.get(r, 0) != cls {
                                continue;
                            }
                            count += 1.0;
                            for (j, &v) in panel.row(r).iter().enumerate() {
                                sums.set(0, j, sums.get(0, j) + v);
                                sq.set(0, j, sq.get(0, j) + v * v);
                            }
                        }
                        Ok(vec![
                            Block::Dense(sums),
                            Block::Dense(sq),
                            Block::Dense(DenseMatrix::full(1, 1, count)),
                        ])
                    }),
                );
                partials.push(out);
            }
            // Master-side reduce (partials are tiny).
            let mut sums = DenseMatrix::zeros(1, f);
            let mut sq = DenseMatrix::zeros(1, f);
            let mut count = 0.0f32;
            for p in partials {
                sums.axpy(1.0, &rt.wait(p[0])?.to_dense()?)?;
                sq.axpy(1.0, &rt.wait(p[1])?.to_dense()?)?;
                count += rt.wait(p[2])?.to_dense()?.get(0, 0);
            }
            if count == 0.0 {
                bail!("class {cls} has no samples");
            }
            let mean = sums.map(|s| s / count);
            let var = DenseMatrix::from_fn(1, f, |_, j| {
                (sq.get(0, j) / count - mean.get(0, j) * mean.get(0, j)).max(0.0)
            });
            means.push(mean);
            vars.push(var);
            priors.push(count as f64 / x.rows() as f64);
        }
        self.classes = classes;
        self.means = means;
        self.vars = vars;
        self.priors = priors;
        Ok(())
    }

    fn predict(&self, x: &DsArray) -> Result<DsArray> {
        if self.classes.is_empty() {
            bail!("predict before fit");
        }
        let x = x.force()?;
        let x = &x;
        let rt = x.runtime().clone();
        let model = Arc::new(GaussianNb {
            classes: self.classes.clone(),
            means: self.means.clone(),
            vars: self.vars.clone(),
            priors: self.priors.clone(),
            var_smoothing: self.var_smoothing,
        });
        let gc = x.grid().1;
        let mut blocks = Vec::with_capacity(x.grid().0);
        for i in 0..x.grid().0 {
            let reads = x.block_row(i);
            let rows = x.block_rows_at(i);
            let model = Arc::clone(&model);
            let out = rt.submit(
                "gnb.predict",
                &reads,
                vec![BlockMeta::dense(rows, 1)],
                CostHint::flops((rows * x.cols() * self.classes.len()) as f64 * 4.0),
                Arc::new(move |ins: &[Arc<Block>]| {
                    let dense: Vec<DenseMatrix> = ins[..gc]
                        .iter()
                        .map(|b| b.to_dense())
                        .collect::<Result<_>>()?;
                    let refs: Vec<&DenseMatrix> = dense.iter().collect();
                    let panel = DenseMatrix::hstack(&refs)?;
                    let mut out = DenseMatrix::zeros(panel.rows(), 1);
                    for r in 0..panel.rows() {
                        let row = panel.row(r);
                        let (mut best_ll, mut best_c) = (f64::NEG_INFINITY, 0.0f32);
                        for (ci, &cls) in model.classes.iter().enumerate() {
                            let ll = model.log_likelihood(row, ci);
                            if ll > best_ll {
                                best_ll = ll;
                                best_c = cls;
                            }
                        }
                        out.set(r, 0, best_c);
                    }
                    Ok(vec![Block::Dense(out)])
                }),
            );
            blocks.push(out[0]);
        }
        DsArray::from_parts(rt, (x.rows(), 1), (x.block_shape().0, 1), blocks, false)
    }

    fn score(&self, x: &DsArray, y: &DsArray) -> Result<f64> {
        let pred = self.predict(x)?.collect()?;
        let truth = y.collect()?;
        let hits = pred
            .data()
            .iter()
            .zip(truth.data())
            .filter(|(p, t)| p == t)
            .count();
        Ok(hits as f64 / truth.rows() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::blobs;
    use crate::dsarray::creation;
    use crate::tasking::Runtime;

    #[test]
    fn separable_blobs_high_accuracy() {
        let rt = Runtime::local(2);
        let (data, truth) = blobs(120, 6, 3, 0.8, 4);
        let x = creation::from_matrix(&rt, &data, (20, 3)).unwrap();
        let y_m = DenseMatrix::from_fn(120, 1, |i, _| truth[i] as f32);
        let y = creation::from_matrix(&rt, &y_m, (20, 1)).unwrap();
        let mut gnb = GaussianNb::default();
        gnb.fit(&x, Some(&y)).unwrap();
        assert_eq!(gnb.classes, vec![0.0, 1.0, 2.0]);
        // Priors sum to 1 and reflect the balanced blobs.
        let psum: f64 = gnb.priors.iter().sum();
        assert!((psum - 1.0).abs() < 1e-9);
        for &p in &gnb.priors {
            assert!((p - 1.0 / 3.0).abs() < 0.05, "prior {p}");
        }
        assert!(gnb.score(&x, &y).unwrap() > 0.98);
    }

    #[test]
    fn fit_on_deferred_fused_input() {
        // The fit entry point must force a deferred elementwise chain
        // (here a standardize-style expression) exactly once, memoized
        // across fit and score.
        let rt = Runtime::local(2);
        let (data, truth) = blobs(120, 6, 3, 0.8, 4);
        let x = creation::from_matrix(&rt, &data, (20, 3)).unwrap();
        let y_m = DenseMatrix::from_fn(120, 1, |i, _| truth[i] as f32);
        let y = creation::from_matrix(&rt, &y_m, (20, 1)).unwrap();
        let lazy = x.mul_scalar(2.0).unwrap().add_scalar(-1.0).unwrap();
        assert!(lazy.is_deferred());
        let before = rt.metrics();
        let mut gnb = GaussianNb::default();
        gnb.fit(&lazy, Some(&y)).unwrap();
        let score = gnb.score(&lazy, &y).unwrap();
        assert!(score > 0.98, "score {score}");
        // The chain materialized once (one fused task per block), not once
        // per estimator entry.
        let d = rt.metrics().since(&before);
        assert_eq!(d.tasks_for("dsarray.ew.fused"), x.n_blocks() as u64);
    }

    #[test]
    fn recovers_class_moments() {
        let rt = Runtime::local(2);
        // Two classes with known means 0 / 10.
        let n = 200;
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(1);
        let data = DenseMatrix::from_fn(n, 2, |i, _| {
            (if i % 2 == 0 { 0.0 } else { 10.0 }) + rng.next_normal()
        });
        let labels = DenseMatrix::from_fn(n, 1, |i, _| (i % 2) as f32);
        let x = creation::from_matrix(&rt, &data, (32, 2)).unwrap();
        let y = creation::from_matrix(&rt, &labels, (32, 1)).unwrap();
        let mut gnb = GaussianNb::default();
        gnb.fit(&x, Some(&y)).unwrap();
        assert!((gnb.means[0].get(0, 0) - 0.0).abs() < 0.3);
        assert!((gnb.means[1].get(0, 0) - 10.0).abs() < 0.3);
        assert!((gnb.vars[0].get(0, 0) - 1.0).abs() < 0.4);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let rt = Runtime::local(1);
        let x = creation::zeros(&rt, (8, 2), (4, 2)).unwrap();
        let mut gnb = GaussianNb::default();
        assert!(gnb.fit(&x, None).is_err());
        // Single class.
        let y = creation::zeros(&rt, (8, 1), (4, 1)).unwrap();
        assert!(gnb.fit(&x, Some(&y)).is_err());
        assert!(GaussianNb::default().predict(&x).is_err());
    }
}
