//! Hot-path microbenchmarks on the REAL (local) executor + PJRT runtime —
//! the measurement harness for the §Perf optimization pass (EXPERIMENTS.md).
//!
//! Measures wall-clock for: block transpose / shuffle / matmul through the
//! task runtime, raw PJRT artifact dispatch (gemm / kmeans / standardize),
//! native block math, and runtime overheads (submit, graph, channels).
//!
//! Usage: cargo bench --bench hotpath [-- --reps 5]

use std::time::Instant;

use anyhow::Result;
use rustdslib::dsarray::creation;
use rustdslib::runtime::{exec, global};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::rng::Xoshiro256;

fn time<F: FnMut() -> Result<()>>(reps: usize, mut f: F) -> Result<f64> {
    // Warmup once (JIT compiles artifacts on first use).
    f()?;
    let t0 = Instant::now();
    for _ in 0..reps {
        f()?;
    }
    Ok(t0.elapsed().as_secs_f64() / reps as f64)
}

fn main() -> Result<()> {
    let args = rustdslib::util::cli::Args::from_env();
    let reps = args.get_usize("reps", 5);
    let workers = args.get_usize("workers", 2);
    let mut rows: Vec<(String, f64, String)> = Vec::new();
    let mut rng = Xoshiro256::seed_from_u64(1);

    // ---- L3: runtime op latencies on real data ----
    let rt = Runtime::local(workers);
    let m = DenseMatrix::from_fn(1024, 1024, |_, _| rng.next_normal());
    let a = creation::from_matrix(&rt, &m, (128, 128))?;

    let t = time(reps, || {
        let t = a.transpose()?;
        t.runtime().barrier()
    })?;
    rows.push(("dsarray.transpose 1024² (64 blocks)".into(), t, format!("{:.1} MB/s", 8.0 / t)));

    let t = time(reps, || {
        let s = a.shuffle_rows(3)?;
        s.runtime().barrier()
    })?;
    rows.push(("dsarray.shuffle 1024²".into(), t, format!("{:.1} MB/s", 8.0 / t)));

    let b = creation::from_matrix(&rt, &m, (128, 128))?;
    let t = time(reps, || {
        let c = a.matmul(&b)?;
        c.runtime().barrier()
    })?;
    let gflops = 2.0 * 1024f64.powi(3) / 1e9;
    rows.push(("dsarray.matmul 1024³".into(), t, format!("{:.2} GFLOP/s", gflops / t)));

    let t = time(reps, || {
        let s = a.sum_axis(0)?;
        s.runtime().barrier()
    })?;
    rows.push(("dsarray.sum_axis(0) 1024²".into(), t, String::new()));

    // ---- View layer: aligned metadata slices vs materialized copies ----
    // Block-aligned slicing is a pure metadata operation (zero tasks, blocks
    // shared); unaligned slicing builds a lazy view whose force() pays one
    // copy task per output block — the paper's §4.2.3 complexity claim.
    let t_view = time(reps * 100, || {
        let v = a.slice_rows(128, 896)?;
        std::hint::black_box(v.shape());
        Ok(())
    })?;
    rows.push((
        "slice aligned 768x1024 (zero-copy view)".into(),
        t_view,
        format!("{:.2} µs", t_view * 1e6),
    ));
    let t_copy = time(reps, || {
        let s = a.slice(100, 868, 50, 1000)?; // unaligned: lazy view
        let c = s.force()?; // materialize: one copy task per block
        c.runtime().barrier()
    })?;
    rows.push((
        "slice unaligned 768x950 (force copy)".into(),
        t_copy,
        format!("{:.0}x aligned view", t_copy / t_view.max(1e-12)),
    ));
    let take_idx: Vec<usize> = (0..512).map(|i| (i * 37) % 1024).collect();
    let t_take = time(reps, || {
        let s = a.take_rows(&take_idx)?;
        let c = s.force()?;
        c.runtime().barrier()
    })?;
    rows.push((
        "take_rows 512 of 1024² (force gather)".into(),
        t_take,
        format!("{:.1} MB/s", 2.0 / t_take),
    ));

    // ---- Task-runtime overhead: empty tasks, one submit per task ----
    let t_serial = time(reps, || {
        let rt2 = Runtime::local(workers);
        let src = rt2.put_block(rustdslib::storage::Block::Dense(DenseMatrix::zeros(1, 1)));
        for _ in 0..1000 {
            rt2.submit(
                "noop",
                &[src],
                vec![rustdslib::storage::BlockMeta::dense(1, 1)],
                rustdslib::tasking::CostHint::default(),
                std::sync::Arc::new(|ins: &[std::sync::Arc<rustdslib::storage::Block>]| {
                    Ok(vec![(*ins[0]).clone()])
                }),
            );
        }
        rt2.barrier()
    })?;
    rows.push((
        "task submit+run x1000 (1x1)".into(),
        t_serial,
        format!("{:.1} µs/task", t_serial * 1e3),
    ));

    // ---- Same 1000 tasks as ONE submit_batch (one lock round-trip) ----
    let t_batch = time(reps, || {
        let rt2 = Runtime::local(workers);
        let src = rt2.put_block(rustdslib::storage::Block::Dense(DenseMatrix::zeros(1, 1)));
        let batch: Vec<rustdslib::tasking::BatchTask> = (0..1000)
            .map(|_| {
                rustdslib::tasking::BatchTask::new(
                    "noop",
                    vec![src],
                    vec![rustdslib::storage::BlockMeta::dense(1, 1)],
                    rustdslib::tasking::CostHint::default(),
                    std::sync::Arc::new(|ins: &[std::sync::Arc<rustdslib::storage::Block>]| {
                        Ok(vec![(*ins[0]).clone()])
                    }),
                )
            })
            .collect();
        rt2.submit_batch(batch);
        rt2.barrier()
    })?;
    rows.push((
        "task submit_batch+run x1000 (1x1)".into(),
        t_batch,
        format!(
            "{:.1} µs/task ({:.2}x vs serial)",
            t_batch * 1e3,
            t_serial / t_batch.max(1e-12)
        ),
    ));

    // ---- Refcount reclamation: rebinding pipeline, bounded residency ----
    let rt3 = Runtime::local(workers);
    let mut cur = creation::from_matrix(&rt3, &m, (128, 128))?;
    for _ in 0..8 {
        cur = cur.add_scalar(1.0)?; // drops the previous generation
    }
    rt3.barrier()?;
    let met = rt3.metrics();
    let produced_mb = 9.0 * 4.0; // 9 generations x 4 MiB each
    rows.push((
        "pipeline 8x add_scalar 1024² resident".into(),
        met.peak_resident_bytes as f64 / (1024.0 * 1024.0),
        format!(
            "MiB peak of {produced_mb:.0} MiB produced, {} blocks evicted",
            met.blocks_evicted
        ),
    ));

    // ---- L1/L2 via PJRT vs native ----
    if let Some(svc) = global() {
        let x = DenseMatrix::from_fn(64, 64, |_, _| rng.next_normal());
        let y = DenseMatrix::from_fn(64, 64, |_, _| rng.next_normal());
        let z = DenseMatrix::zeros(64, 64);
        let t = time(reps * 10, || exec::gemm_acc(svc, &x, &y, &z).map(|_| ()))?;
        let fl = 2.0 * 64f64.powi(3) / 1e9;
        rows.push(("pjrt gemm_64".into(), t, format!("{:.2} GFLOP/s", fl / t)));

        let x128 = DenseMatrix::from_fn(128, 128, |_, _| rng.next_normal());
        let y128 = DenseMatrix::from_fn(128, 128, |_, _| rng.next_normal());
        let z128 = DenseMatrix::zeros(128, 128);
        let t = time(reps * 10, || exec::gemm_acc(svc, &x128, &y128, &z128).map(|_| ()))?;
        let fl = 2.0 * 128f64.powi(3) / 1e9;
        rows.push(("pjrt gemm_128".into(), t, format!("{:.2} GFLOP/s", fl / t)));

        let t = time(reps * 10, || {
            x.matmul(&y).map(|_| ())
        })?;
        let fl = 2.0 * 64f64.powi(3) / 1e9;
        rows.push(("native matmul 64³".into(), t, format!("{:.2} GFLOP/s", fl / t)));

        let centers = DenseMatrix::from_fn(8, 64, |_, _| rng.next_normal());
        let t = time(reps * 10, || {
            exec::kmeans_assign(svc, &x, &centers).map(|_| ())
        })?;
        rows.push(("pjrt kmeans_64 (fused)".into(), t, format!("{:.0} µs", t * 1e6)));

        let mu = DenseMatrix::zeros(1, 64);
        let is = DenseMatrix::full(1, 64, 1.0);
        let t = time(reps * 10, || exec::standardize(svc, &x, &mu, &is).map(|_| ()))?;
        rows.push(("pjrt standardize_64".into(), t, format!("{:.0} µs", t * 1e6)));
    } else {
        rows.push(("pjrt".into(), f64::NAN, "artifacts not built".into()));
    }

    println!("{:<40} {:>12} {:>22}", "op", "secs/iter", "rate");
    println!("{}", "-".repeat(76));
    for (name, secs, rate) in rows {
        println!("{name:<40} {secs:>12.6} {rate:>22}");
    }
    // Machine-readable residency/eviction counters (satellite: JSON out).
    println!(
        "\npipeline-metrics: {}",
        rustdslib::bench::report::metrics_json(&met)
    );
    Ok(())
}
