//! Hot-path microbenchmarks on the REAL (local) executor + PJRT runtime —
//! the measurement harness for the §Perf optimization pass (EXPERIMENTS.md).
//!
//! Measures wall-clock for: block transpose / shuffle / matmul through the
//! task runtime, the fused elementwise engine (fused vs per-op chains,
//! in-place vs copy execution), the tiled gemm-accumulate kernel vs the old
//! product+axpy pattern, the kernel layer (scalar vs detected SIMD tables:
//! gemm, elementwise chain, pairwise distances) and intra-block splitting
//! (whole fat-block task vs sub-range work items), raw PJRT artifact
//! dispatch, native block math, runtime overheads (submit, graph,
//! channels), the elasticity paths (drain-time block migration, straggler
//! speculation on a stalling worker), the serving tier (single-row
//! predict p50/p99 latency and throughput through the micro-batcher,
//! coalesced vs uncoalesced), and the plan layer (gemm + elementwise
//! epilogue and the KMeans/ALS fits at optimizer off vs full — grafted
//! epilogues and composed reduce tails, task counts in the notes).
//!
//! Usage: cargo bench --bench hotpath [-- --reps 5 --json BENCH_hotpath.json]

use std::time::Instant;

use anyhow::Result;
use rustdslib::dsarray::creation;
use rustdslib::kernels::{self, UnaryKind};
use rustdslib::runtime::{exec, global};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::rng::Xoshiro256;

fn time<F: FnMut() -> Result<()>>(reps: usize, mut f: F) -> Result<f64> {
    // Warmup once (JIT compiles artifacts on first use).
    f()?;
    let t0 = Instant::now();
    for _ in 0..reps {
        f()?;
    }
    Ok(t0.elapsed().as_secs_f64() / reps as f64)
}

fn main() -> Result<()> {
    let args = rustdslib::util::cli::Args::from_env();
    let reps = args.get_usize("reps", 5);
    let workers = args.get_usize("workers", 2);
    let mut rows: Vec<(String, f64, String)> = Vec::new();
    let mut rng = Xoshiro256::seed_from_u64(1);

    // ---- L3: runtime op latencies on real data ----
    let rt = Runtime::local(workers);
    let m = DenseMatrix::from_fn(1024, 1024, |_, _| rng.next_normal());
    let a = creation::from_matrix(&rt, &m, (128, 128))?;

    let t = time(reps, || {
        let t = a.transpose()?;
        t.runtime().barrier()
    })?;
    rows.push(("dsarray.transpose 1024² (64 blocks)".into(), t, format!("{:.1} MB/s", 8.0 / t)));

    let t = time(reps, || {
        let s = a.shuffle_rows(3)?;
        s.runtime().barrier()
    })?;
    rows.push(("dsarray.shuffle 1024²".into(), t, format!("{:.1} MB/s", 8.0 / t)));

    let b = creation::from_matrix(&rt, &m, (128, 128))?;
    let t = time(reps, || {
        let c = a.matmul(&b)?;
        c.runtime().barrier()
    })?;
    let gflops = 2.0 * 1024f64.powi(3) / 1e9;
    rows.push(("dsarray.matmul 1024³".into(), t, format!("{:.2} GFLOP/s", gflops / t)));

    let t = time(reps, || {
        let s = a.sum_axis(0)?;
        s.runtime().barrier()
    })?;
    rows.push(("dsarray.sum_axis(0) 1024²".into(), t, String::new()));

    // ---- View layer: aligned metadata slices vs materialized copies ----
    // Block-aligned slicing is a pure metadata operation (zero tasks, blocks
    // shared); unaligned slicing builds a lazy view whose force() pays one
    // copy task per output block — the paper's §4.2.3 complexity claim.
    let t_view = time(reps * 100, || {
        let v = a.slice_rows(128, 896)?;
        std::hint::black_box(v.shape());
        Ok(())
    })?;
    rows.push((
        "slice aligned 768x1024 (zero-copy view)".into(),
        t_view,
        format!("{:.2} µs", t_view * 1e6),
    ));
    let t_copy = time(reps, || {
        let s = a.slice(100, 868, 50, 1000)?; // unaligned: lazy view
        let c = s.force()?; // materialize: one copy task per block
        c.runtime().barrier()
    })?;
    rows.push((
        "slice unaligned 768x950 (force copy)".into(),
        t_copy,
        format!("{:.0}x aligned view", t_copy / t_view.max(1e-12)),
    ));
    let take_idx: Vec<usize> = (0..512).map(|i| (i * 37) % 1024).collect();
    let t_take = time(reps, || {
        let s = a.take_rows(&take_idx)?;
        let c = s.force()?;
        c.runtime().barrier()
    })?;
    rows.push((
        "take_rows 512 of 1024² (force gather)".into(),
        t_take,
        format!("{:.1} MB/s", 2.0 / t_take),
    ));

    // ---- Fused elementwise engine: 3-op chain, fused vs per-op ----
    // The per-op variant forces after every op (one task + one allocation
    // per op per block — the pre-fusion behavior); the fused variant defers
    // and collapses to ONE task per block.
    let t_perop = time(reps, || {
        let c = a
            .add_scalar(1.0)?
            .force()?
            .mul_scalar(0.5)?
            .force()?
            .add_scalar(-3.0)?
            .force()?;
        c.runtime().barrier()
    })?;
    rows.push((
        "ew chain 3 ops 1024² per-op (forced)".into(),
        t_perop,
        format!("{:.1} MB/s", 3.0 * 4.0 / t_perop),
    ));
    let t_fused = time(reps, || {
        let c = a
            .add_scalar(1.0)?
            .mul_scalar(0.5)?
            .add_scalar(-3.0)?
            .force()?;
        c.runtime().barrier()
    })?;
    rows.push((
        "ew chain 3 ops 1024² fused".into(),
        t_fused,
        format!("{:.2}x vs per-op", t_perop / t_fused.max(1e-12)),
    ));

    // ---- In-place vs copy execution of a fused chain ----
    // Copy: the chain's input stays alive, so every block is copied once.
    let t_copy_ew = time(reps, || {
        let tmp = a.add_scalar(0.0)?.force()?;
        tmp.runtime().barrier()?;
        let c = tmp.mul_scalar(1.0001)?.add_scalar(0.5)?.force()?; // tmp alive
        c.runtime().barrier()
    })?;
    rows.push((
        "ew fused 1024² copy (input alive)".into(),
        t_copy_ew,
        String::new(),
    ));
    // In-place: the input dies before materialization, so the executor
    // grants every block to the fused closure for in-place mutation.
    let rt_ip = Runtime::local(workers);
    let a_ip = creation::from_matrix(&rt_ip, &m, (128, 128))?;
    let before_ip = rt_ip.metrics();
    let t_inplace_ew = time(reps, || {
        let tmp = a_ip.add_scalar(0.0)?.force()?;
        tmp.runtime().barrier()?;
        let chain = tmp.mul_scalar(1.0001)?.add_scalar(0.5)?;
        drop(tmp); // sole owner gone: blocks are granted in place
        let c = chain.force()?;
        c.runtime().barrier()
    })?;
    // time() executes warmup + reps runs; report grants per run so the
    // JSON artifact is comparable across rep counts.
    let ip_hits = rt_ip.metrics().since(&before_ip).inplace_hits / (reps as u64 + 1);
    rows.push((
        "ew fused 1024² in-place (input dead)".into(),
        t_inplace_ew,
        format!(
            "{:.2}x vs copy, {ip_hits} grants/run",
            t_copy_ew / t_inplace_ew.max(1e-12)
        ),
    ));

    // ---- Tiled gemm-accumulate vs old product+axpy, per block size ----
    // Old pattern: allocate the product, then a second full pass to add it
    // (what the blocked matmul inner loop used to do per k-step).
    for bs in [64usize, 128, 256] {
        let x = DenseMatrix::from_fn(bs, bs, |_, _| rng.next_normal());
        let y = DenseMatrix::from_fn(bs, bs, |_, _| rng.next_normal());
        let steps = 8;
        let fl = steps as f64 * 2.0 * (bs as f64).powi(3) / 1e9;
        let t_old = time(reps, || {
            let mut acc = DenseMatrix::zeros(bs, bs);
            for _ in 0..steps {
                let prod = x.matmul(&y)?;
                acc.axpy(1.0, &prod)?;
            }
            std::hint::black_box(acc.get(0, 0));
            Ok(())
        })?;
        rows.push((
            format!("gemm {bs}³ x{steps} old (prod+axpy)"),
            t_old,
            format!("{:.2} GFLOP/s", fl / t_old),
        ));
        let t_tiled = time(reps, || {
            let mut acc = DenseMatrix::zeros(bs, bs);
            for _ in 0..steps {
                acc.gemm_acc(&x, &y)?;
            }
            std::hint::black_box(acc.get(0, 0));
            Ok(())
        })?;
        rows.push((
            format!("gemm {bs}³ x{steps} tiled gemm_acc"),
            t_tiled,
            format!(
                "{:.2} GFLOP/s ({:.2}x vs old)",
                fl / t_tiled,
                t_old / t_tiled.max(1e-12)
            ),
        ));
    }

    // ---- Kernel layer: scalar vs detected (SIMD) tables, direct calls ----
    // No task runtime in these rows — they isolate the micro-kernel speedup
    // itself. Both tables are bit-identical by contract, so this is a pure
    // throughput comparison. The `detected` rows keep stable names (the
    // actual table — avx2 or scalar fallback — goes in the note).
    let ker_s = kernels::scalar();
    let ker_d = kernels::detected();
    for n in [64usize, 256, 1024] {
        let x = DenseMatrix::from_fn(n, n, |_, _| rng.next_normal());
        let y = DenseMatrix::from_fn(n, n, |_, _| rng.next_normal());
        let fl = 2.0 * (n as f64).powi(3) / 1e9;
        let reps_k = if n >= 1024 { reps } else { reps * 10 };
        let t_s = time(reps_k, || {
            let mut c = DenseMatrix::zeros(n, n);
            (ker_s.gemm_acc)(c.data_mut(), x.data(), y.data(), n, n, n);
            std::hint::black_box(c.get(0, 0));
            Ok(())
        })?;
        rows.push((
            format!("kernel gemm {n}³ scalar"),
            t_s,
            format!("{:.2} GFLOP/s", fl / t_s),
        ));
        let t_d = time(reps_k, || {
            let mut c = DenseMatrix::zeros(n, n);
            (ker_d.gemm_acc)(c.data_mut(), x.data(), y.data(), n, n, n);
            std::hint::black_box(c.get(0, 0));
            Ok(())
        })?;
        rows.push((
            format!("kernel gemm {n}³ detected"),
            t_d,
            format!(
                "{:.2} GFLOP/s ({}, {:.2}x vs scalar)",
                fl / t_d,
                ker_d.name,
                t_s / t_d.max(1e-12)
            ),
        ));
    }
    // Interpreted elementwise chain over one 1M-element buffer (the inner
    // loop of the fused executor, minus the task plumbing).
    let ew_src: Vec<f32> = (0..1 << 20).map(|_| rng.next_normal()).collect();
    let ew_chain = [
        UnaryKind::AddScalar(1.0),
        UnaryKind::MulScalar(0.5),
        UnaryKind::AddScalar(-3.0),
    ];
    let t_ew_s = time(reps, || {
        let mut xs = ew_src.clone();
        for op in ew_chain {
            (ker_s.unary)(op, &mut xs);
        }
        std::hint::black_box(xs[0]);
        Ok(())
    })?;
    rows.push((
        "kernel ew chain 3 ops 1M scalar".into(),
        t_ew_s,
        format!("{:.1} MB/s", 3.0 * 4.0 / t_ew_s),
    ));
    let t_ew_d = time(reps, || {
        let mut xs = ew_src.clone();
        for op in ew_chain {
            (ker_d.unary)(op, &mut xs);
        }
        std::hint::black_box(xs[0]);
        Ok(())
    })?;
    rows.push((
        "kernel ew chain 3 ops 1M detected".into(),
        t_ew_d,
        format!(
            "{:.1} MB/s ({}, {:.2}x vs scalar)",
            3.0 * 4.0 / t_ew_d,
            ker_d.name,
            t_ew_s / t_ew_d.max(1e-12)
        ),
    ));
    // Pairwise squared distances, 256×256 row pairs over 64 features.
    let px = DenseMatrix::from_fn(256, 64, |_, _| rng.next_normal());
    let py = DenseMatrix::from_fn(256, 64, |_, _| rng.next_normal());
    let pd_fl = 3.0 * 256.0 * 256.0 * 64.0 / 1e9;
    let t_pd_s = time(reps, || {
        let mut acc = 0.0f32;
        for i in 0..256 {
            for j in 0..256 {
                acc += (ker_s.dist2)(px.row(i), py.row(j));
            }
        }
        std::hint::black_box(acc);
        Ok(())
    })?;
    rows.push((
        "kernel pairwise dist2 256x256x64 scalar".into(),
        t_pd_s,
        format!("{:.2} GFLOP/s", pd_fl / t_pd_s),
    ));
    let t_pd_d = time(reps, || {
        let mut acc = 0.0f32;
        for i in 0..256 {
            for j in 0..256 {
                acc += (ker_d.dist2)(px.row(i), py.row(j));
            }
        }
        std::hint::black_box(acc);
        Ok(())
    })?;
    rows.push((
        "kernel pairwise dist2 256x256x64 detected".into(),
        t_pd_d,
        format!(
            "{:.2} GFLOP/s ({}, {:.2}x vs scalar)",
            pd_fl / t_pd_d,
            ker_d.name,
            t_pd_s / t_pd_d.max(1e-12)
        ),
    ));

    // ---- Intra-block splitting: one fat single-block gemm task, whole
    // (split threshold at max) vs sub-range work items on the worker
    // deques. Same kernel table both ways — the delta is pure parallelism.
    let fat = 512usize;
    let fat_m = DenseMatrix::from_fn(fat, fat, |_, _| rng.next_normal());
    let fat_fl = 2.0 * (fat as f64).powi(3) / 1e9;
    let split_prev = kernels::set_split_min(usize::MAX);
    let t_whole = time(reps, || {
        let rt2 = Runtime::local(workers);
        let fa = creation::from_matrix(&rt2, &fat_m, (fat, fat))?;
        let fb = creation::from_matrix(&rt2, &fat_m, (fat, fat))?;
        let c = fa.matmul(&fb)?;
        c.runtime().barrier()
    })?;
    rows.push((
        "split gemm 512³ single-block whole".into(),
        t_whole,
        format!("{:.2} GFLOP/s", fat_fl / t_whole),
    ));
    kernels::set_split_min(1 << 16);
    let mut fat_subs = 0u64;
    let t_split = time(reps, || {
        let rt2 = Runtime::local(workers);
        let fa = creation::from_matrix(&rt2, &fat_m, (fat, fat))?;
        let fb = creation::from_matrix(&rt2, &fat_m, (fat, fat))?;
        let c = fa.matmul(&fb)?;
        c.runtime().barrier()?;
        fat_subs = rt2.metrics().subtasks_spawned;
        Ok(())
    })?;
    kernels::set_split_min(split_prev);
    rows.push((
        "split gemm 512³ single-block sub-tasks".into(),
        t_split,
        format!(
            "{:.2} GFLOP/s ({:.2}x vs whole, {fat_subs} sub-tasks/run)",
            fat_fl / t_split,
            t_whole / t_split.max(1e-12)
        ),
    ));

    // ---- Parallel partitioned load: serial baseline vs 1/4/16 block-rows ----
    // Serial = master-side read + scatter (the pre-out-of-core path); the
    // parallel loader splits the file by byte ranges and parses one task
    // per block-row, so parallelism scales with the row blocking.
    let load_m = DenseMatrix::from_fn(512, 64, |_, _| rng.next_normal());
    let csv_path = std::env::temp_dir().join(format!(
        "rustdslib_bench_load_{}.csv",
        std::process::id()
    ));
    rustdslib::storage::io::write_csv(&csv_path, &load_m, ',')?;
    let load_mb = (512 * 64 * 4) as f64 / (1024.0 * 1024.0);
    let t_serial_load = time(reps, || {
        let rt2 = Runtime::local(workers);
        let m = rustdslib::storage::io::read_csv(&csv_path, ',')?;
        let a = creation::from_matrix(&rt2, &m, (512, 64))?;
        a.runtime().barrier()
    })?;
    rows.push((
        "load csv 512x64 serial (read+scatter)".into(),
        t_serial_load,
        format!("{:.1} MB/s", load_mb / t_serial_load),
    ));
    for nb in [1usize, 4, 16] {
        let t = time(reps, || {
            let rt2 = Runtime::local(workers);
            let a = rustdslib::dsarray::io::load_csv(&rt2, &csv_path, (512 / nb, 64), ',')?;
            a.runtime().barrier()
        })?;
        rows.push((
            format!("load csv 512x64 parallel {nb} block-row{}", if nb > 1 { "s" } else { "" }),
            t,
            format!("{:.1} MB/s ({:.2}x vs serial)", load_mb / t, t_serial_load / t.max(1e-12)),
        ));
    }
    std::fs::remove_file(&csv_path).ok();

    // ---- In-memory vs spill-backed matmul (budget = half of one operand) ----
    let mm = DenseMatrix::from_fn(256, 256, |_, _| rng.next_normal());
    let mm_gflops = 2.0 * 256f64.powi(3) / 1e9;
    let t_mm_mem = time(reps, || {
        let rt2 = Runtime::local(workers);
        let a = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let b = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let c = a.matmul(&b)?;
        c.runtime().barrier()
    })?;
    rows.push((
        "matmul 256³ in-memory".into(),
        t_mm_mem,
        format!("{:.2} GFLOP/s", mm_gflops / t_mm_mem),
    ));
    let (mut spilled, mut faulted) = (0u64, 0u64);
    let t_mm_ooc = time(reps, || {
        // Budget: half of ONE operand — all three arrays stream through it.
        let rt2 = Runtime::local_with_budget(workers, 256 * 256 * 4 / 2)?;
        let a = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let b = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let c = a.matmul(&b)?;
        c.runtime().barrier()?;
        let met = rt2.metrics();
        (spilled, faulted) = (met.blocks_spilled, met.blocks_faulted);
        Ok(())
    })?;
    rows.push((
        "matmul 256³ spill-backed (budget ½ operand)".into(),
        t_mm_ooc,
        format!(
            "{:.2} GFLOP/s, {spilled} spills/{faulted} faults, {:.2}x in-memory cost",
            mm_gflops / t_mm_ooc,
            t_mm_ooc / t_mm_mem.max(1e-12)
        ),
    ));

    // ---- Same gemm through the cluster backend: 2 in-process TCP workers
    // (same wire protocol and daemon loop as `dsarray worker` processes).
    // Deliberately named without the gated row-group words: wall time here
    // includes loopback TCP and is noisier than the compute rows.
    let spawn_worker = || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = rustdslib::tasking::cluster::serve_worker(
                l,
                rustdslib::tasking::WorkerOptions::default(),
            );
        });
        addr
    };
    let (mut wire_mib, mut loc_hits, mut loc_misses) = (0.0f64, 0u64, 0u64);
    let t_mm_cluster = time(reps, || {
        let rt2 = Runtime::cluster(rustdslib::tasking::ClusterOptions {
            addrs: vec![spawn_worker(), spawn_worker()],
            threads: workers.max(1),
            ..Default::default()
        })?;
        let a = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let b = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let c = a.matmul(&b)?;
        c.runtime().barrier()?;
        let met = rt2.metrics();
        wire_mib = met.bytes_on_wire as f64 / (1024.0 * 1024.0);
        loc_hits = met.locality_hits;
        loc_misses = met.remote_transfers;
        Ok(())
    })?;
    rows.push((
        "cluster gemm-over-wire 256³ (2 workers)".into(),
        t_mm_cluster,
        format!(
            "{:.2} GFLOP/s, {wire_mib:.1} MiB wire, {loc_hits} hits/{loc_misses} transfers, {:.2}x in-memory",
            mm_gflops / t_mm_cluster,
            t_mm_cluster / t_mm_mem.max(1e-12)
        ),
    ));

    // ---- Recovery: the same gemm with one worker crashed mid-run ----
    // Wall time covers detection (a failed socket), the lineage walk, root
    // re-loads from the coordinator journal, and replaying the lost
    // sub-graph on the survivor. Gated as the `recovery` row group.
    let crash_worker = |addr: &str| {
        use rustdslib::tasking::wire::{self, Request};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        wire::write_request(&mut s, &Request::Crash).unwrap();
        let _ = wire::read_response(&mut s);
    };
    let (mut rec_replays, mut rec_ms) = (0u64, 0u64);
    let t_mm_recover = time(reps, || {
        let w0 = spawn_worker();
        let w1 = spawn_worker();
        let rt2 = Runtime::cluster(rustdslib::tasking::ClusterOptions {
            addrs: vec![w0.clone(), w1],
            threads: workers.max(1),
            ..Default::default()
        })?;
        let a = creation::from_matrix(&rt2, &mm, (64, 64))?;
        let b = creation::from_matrix(&rt2, &mm, (64, 64))?;
        rt2.barrier()?;
        let c = a.matmul(&b)?;
        // Half of every operand dies with this worker while gemm tasks are
        // in flight; the barrier returns only after full re-materialization.
        crash_worker(&w0);
        c.runtime().barrier()?;
        let met = rt2.metrics();
        rec_replays = met.tasks_replayed;
        rec_ms = met.recovery_ms;
        Ok(())
    })?;
    rows.push((
        "recovery kill-mid-gemm 256³ (2 workers)".into(),
        t_mm_recover,
        format!(
            "{rec_replays} replays, {rec_ms} ms recorded, {:.2}x fault-free cluster",
            t_mm_recover / t_mm_cluster.max(1e-12)
        ),
    ));

    // ---- Elasticity rows (gated as the `elastic` group) ----
    // Drain-migration: decommission one of two workers holding half of a
    // 16-block array; wall time covers the sole-copy Pull migration plus a
    // full collect served entirely by the survivor, with zero replays.
    // Every run needs a fresh fleet — a drained member stays drained.
    let (mut drain_mib, mut drain_replays) = (0.0f64, 0u64);
    let t_drain = time(reps, || {
        let rt2 = Runtime::cluster(rustdslib::tasking::ClusterOptions {
            addrs: vec![spawn_worker(), spawn_worker()],
            threads: workers.max(1),
            ..Default::default()
        })?;
        let a = creation::from_matrix(&rt2, &mm, (64, 64))?;
        rt2.barrier()?;
        let before = rt2.metrics();
        rt2.cluster_drain(0)?;
        drain_mib = rt2.metrics().since(&before).bytes_on_wire as f64 / (1024.0 * 1024.0);
        let v = a.collect()?;
        std::hint::black_box(v.get(0, 0));
        drain_replays = rt2.metrics().tasks_replayed;
        Ok(())
    })?;
    rows.push((
        "elastic drain-migrate 256² (2 workers)".into(),
        t_drain,
        format!("{drain_mib:.1} MiB migrated, {drain_replays} replays"),
    ));

    // Straggler speculation: the same small gemm with one worker that
    // stalls 800 ms per request from its 8th request on. The baseline
    // serializes those stalls; with speculation the monitor re-arms the
    // stuck tasks on the healthy worker and first-completion wins. Fresh
    // workers per run: the deterministic fault schedule is consumed.
    let spawn_slow_worker = || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let opts = rustdslib::tasking::WorkerOptions {
            fault_spec: Some("slow@8".to_string()),
            ..Default::default()
        };
        std::thread::spawn(move || {
            let _ = rustdslib::tasking::cluster::serve_worker(l, opts);
        });
        addr
    };
    let sm = DenseMatrix::from_fn(128, 128, |_, _| rng.next_normal());
    let sm_gflops = 2.0 * 128f64.powi(3) / 1e9;
    let reps_e = reps.clamp(1, 2); // the stall-bound baseline is slow by design
    let straggler_gemm = |factor: f64| -> Result<(f64, u64)> {
        let mut speculated = 0u64;
        let t = time(reps_e, || {
            let rt2 = Runtime::cluster(rustdslib::tasking::ClusterOptions {
                addrs: vec![spawn_worker(), spawn_slow_worker()],
                threads: workers.max(1),
                straggler_factor: factor.max(0.0),
                ..Default::default()
            })?;
            let a = creation::from_matrix(&rt2, &sm, (64, 64))?;
            let b = creation::from_matrix(&rt2, &sm, (64, 64))?;
            let c = a.matmul(&b)?;
            c.runtime().barrier()?;
            speculated = rt2.metrics().tasks_speculated;
            Ok(())
        })?;
        Ok((t, speculated))
    };
    let (t_stall, _) = straggler_gemm(0.0)?;
    rows.push((
        "elastic straggler gemm 128³ no-speculation".into(),
        t_stall,
        format!("{:.2} GFLOP/s", sm_gflops / t_stall),
    ));
    let (t_spec, n_spec) = straggler_gemm(2.5)?;
    rows.push((
        "elastic straggler gemm 128³ speculation".into(),
        t_spec,
        format!(
            "{:.2} GFLOP/s ({:.2}x vs stalled, {n_spec} speculated/run)",
            sm_gflops / t_spec,
            t_stall / t_spec.max(1e-12)
        ),
    ));

    // ---- Serving tier (gated as the `serving` group): single-row predict
    // latency through the micro-batcher over 2 in-process TCP workers. Row
    // value is the p50 request latency; p99, throughput and coalescing ride
    // in the note. The uncoalesced baseline (window 0, one sequential
    // client) isolates what the batch window buys under concurrency.
    let serve_xm = DenseMatrix::from_fn(256, 8, |i, _| (i % 4) as f32 * 5.0 + rng.next_normal());
    let serve_rt_fit = Runtime::local(workers);
    let serve_x = creation::from_matrix(&serve_rt_fit, &serve_xm, (64, 8))?;
    let mut serve_km = rustdslib::estimators::kmeans::KMeans::new(
        rustdslib::estimators::kmeans::KMeansConfig {
            k: 4,
            max_iter: 8,
            tol: 1e-9,
            seed: 7,
        },
    );
    serve_km.fit_dsarray(&serve_x)?;
    let serve_artifact = rustdslib::serving::ModelArtifact::from_kmeans(&serve_km)?;
    // Returns (sorted request latencies, coalesced batches, traffic wall).
    let run_serving = |window_ms: u64, clients: usize, per_client: usize| -> Result<(Vec<f64>, u64, f64)> {
        let rt2 = Runtime::cluster(rustdslib::tasking::ClusterOptions {
            addrs: vec![spawn_worker(), spawn_worker()],
            threads: workers.max(1),
            ..Default::default()
        })?;
        let server = rustdslib::serving::ModelServer::new(
            rt2,
            rustdslib::serving::ServeOptions::default().with_batch_window_ms(window_ms),
        );
        server.register("km", serve_artifact.clone())?;
        let handle = server.serve(std::net::TcpListener::bind("127.0.0.1:0")?)?;
        let addr = handle.addr().to_string();
        let t0 = Instant::now();
        let threads: Vec<_> = (0..clients)
            .map(|t| {
                let addr = addr.clone();
                let xm = serve_xm.clone();
                std::thread::spawn(move || -> Result<Vec<f64>> {
                    let mut c = rustdslib::serving::ServingClient::connect(&addr)?;
                    let mut lat = Vec::with_capacity(per_client);
                    for k in 0..per_client {
                        let i = (t * per_client + k) % xm.rows();
                        let row = xm.slice(i, 0, 1, xm.cols())?;
                        let q0 = Instant::now();
                        let out = c.predict("km", &row)?;
                        lat.push(q0.elapsed().as_secs_f64());
                        std::hint::black_box(&out);
                    }
                    Ok(lat)
                })
            })
            .collect();
        let mut lats = Vec::new();
        for t in threads {
            lats.extend(t.join().unwrap()?);
        }
        let wall = t0.elapsed().as_secs_f64();
        let coalesced = handle.stats().batches_coalesced;
        handle.shutdown();
        lats.sort_by(|x, y| x.partial_cmp(y).unwrap());
        Ok((lats, coalesced, wall))
    };
    let pct = |l: &[f64], q: f64| l[((l.len() - 1) as f64 * q) as usize];
    let (lat_un, _, wall_un) = run_serving(0, 1, 200)?;
    rows.push((
        "serving predict 1-row uncoalesced".into(),
        pct(&lat_un, 0.5),
        format!(
            "p99 {:.0} µs, {:.0} pred/s",
            pct(&lat_un, 0.99) * 1e6,
            lat_un.len() as f64 / wall_un.max(1e-12)
        ),
    ));
    let (lat_co, n_co, wall_co) = run_serving(2, 8, 100)?;
    rows.push((
        "serving predict 1-row coalesced (8 clients)".into(),
        pct(&lat_co, 0.5),
        format!(
            "p99 {:.0} µs, {:.0} pred/s, {n_co} coalesced batches",
            pct(&lat_co, 0.99) * 1e6,
            lat_co.len() as f64 / wall_co.max(1e-12)
        ),
    ));

    // ---- Plan layer (gated as the `planner` group): the same programs at
    // optimizer off vs full. Results are bit-identical by contract; the
    // interesting deltas are the task counts in the notes — the grafted
    // epilogue removes the separate elementwise pass, and the composed
    // estimator reduce tails remove one task per reduce.
    {
        use rustdslib::estimators::als::{Als, AlsConfig};
        use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
        use rustdslib::plan::Level;

        let pm = DenseMatrix::from_fn(512, 512, |_, _| rng.next_normal());
        let plan_gflops = 2.0 * 512f64.powi(3) / 1e9;
        let plan_gemm = |level: Level| -> Result<(f64, u64, u64)> {
            let (mut tasks, mut fused) = (0u64, 0u64);
            let t = time(reps, || {
                let rt2 = Runtime::builder().workers(workers).optimizer(level).build()?;
                let a = creation::from_matrix(&rt2, &pm, (128, 128))?;
                let b = creation::from_matrix(&rt2, &pm, (128, 128))?;
                let c = a.matmul(&b)?.mul_scalar(0.5)?.add_scalar(1.0)?.force()?;
                c.runtime().barrier()?;
                let met = rt2.metrics();
                tasks = met.total_tasks();
                fused = met.tasks_for("dsarray.matmul.fused");
                Ok(())
            })?;
            Ok((t, tasks, fused))
        };
        let (t_po, tasks_po, _) = plan_gemm(Level::Off)?;
        rows.push((
            "planner gemm+epilogue 512³ off (ew pass)".into(),
            t_po,
            format!("{:.2} GFLOP/s, {tasks_po} tasks/run", plan_gflops / t_po),
        ));
        let (t_pf, tasks_pf, fused_pf) = plan_gemm(Level::Full)?;
        rows.push((
            "planner gemm+epilogue 512³ full (grafted)".into(),
            t_pf,
            format!(
                "{:.2} GFLOP/s ({:.2}x vs off), {tasks_pf} tasks/run, {fused_pf} grafted",
                plan_gflops / t_pf,
                t_po / t_pf.max(1e-12)
            ),
        ));

        let km_m = DenseMatrix::from_fn(512, 16, |i, _| (i % 4) as f32 * 4.0 + rng.next_normal());
        let plan_kmeans = |level: Level| -> Result<(f64, u64)> {
            let mut tasks = 0u64;
            let t = time(reps, || {
                let rt2 = Runtime::builder().workers(workers).optimizer(level).build()?;
                let x = creation::from_matrix(&rt2, &km_m, (64, 16))?;
                let mut km = KMeans::new(KMeansConfig {
                    k: 4,
                    max_iter: 8,
                    tol: 1e-9,
                    seed: 7,
                });
                km.fit_dsarray(&x)?;
                tasks = rt2.metrics().total_tasks();
                Ok(())
            })?;
            Ok((t, tasks))
        };
        let (t_ko, tasks_ko) = plan_kmeans(Level::Off)?;
        rows.push((
            "planner kmeans fit 512x16 off".into(),
            t_ko,
            format!("{tasks_ko} tasks/run"),
        ));
        let (t_kf, tasks_kf) = plan_kmeans(Level::Full)?;
        rows.push((
            "planner kmeans fit 512x16 full (composed)".into(),
            t_kf,
            format!(
                "{tasks_kf} tasks/run ({} fewer, {:.2}x vs off)",
                tasks_ko.saturating_sub(tasks_kf),
                t_ko / t_kf.max(1e-12)
            ),
        ));

        let als_m = DenseMatrix::from_fn(96, 64, |_, _| rng.next_normal());
        let plan_als = |level: Level| -> Result<(f64, u64)> {
            let mut tasks = 0u64;
            let t = time(reps, || {
                let rt2 = Runtime::builder().workers(workers).optimizer(level).build()?;
                let r = creation::from_matrix(&rt2, &als_m, (24, 16))?;
                let mut als = Als::new(AlsConfig {
                    d: 8,
                    lambda: 0.1,
                    max_iter: 3,
                    seed: 9,
                });
                als.fit_dsarray(&r)?;
                tasks = rt2.metrics().total_tasks();
                Ok(())
            })?;
            Ok((t, tasks))
        };
        let (t_ao, tasks_ao) = plan_als(Level::Off)?;
        rows.push((
            "planner als fit 96x64 off".into(),
            t_ao,
            format!("{tasks_ao} tasks/run"),
        ));
        let (t_af, tasks_af) = plan_als(Level::Full)?;
        rows.push((
            "planner als fit 96x64 full (composed)".into(),
            t_af,
            format!(
                "{tasks_af} tasks/run ({} fewer, {:.2}x vs off)",
                tasks_ao.saturating_sub(tasks_af),
                t_ao / t_af.max(1e-12)
            ),
        ));
    }

    // ---- Task-runtime overhead: empty tasks, one submit per task ----
    let t_serial = time(reps, || {
        let rt2 = Runtime::local(workers);
        let src = rt2.put_block(rustdslib::storage::Block::Dense(DenseMatrix::zeros(1, 1)));
        for _ in 0..1000 {
            rt2.submit(
                "noop",
                &[src],
                vec![rustdslib::storage::BlockMeta::dense(1, 1)],
                rustdslib::tasking::CostHint::default(),
                std::sync::Arc::new(|ins: &[std::sync::Arc<rustdslib::storage::Block>]| {
                    Ok(vec![(*ins[0]).clone()])
                }),
            );
        }
        rt2.barrier()
    })?;
    rows.push((
        "task submit+run x1000 (1x1)".into(),
        t_serial,
        format!("{:.1} µs/task", t_serial * 1e3),
    ));

    // ---- Same 1000 tasks as ONE submit_batch (one lock round-trip) ----
    let t_batch = time(reps, || {
        let rt2 = Runtime::local(workers);
        let src = rt2.put_block(rustdslib::storage::Block::Dense(DenseMatrix::zeros(1, 1)));
        let batch: Vec<rustdslib::tasking::BatchTask> = (0..1000)
            .map(|_| {
                rustdslib::tasking::BatchTask::new(
                    "noop",
                    vec![src],
                    vec![rustdslib::storage::BlockMeta::dense(1, 1)],
                    rustdslib::tasking::CostHint::default(),
                    std::sync::Arc::new(|ins: &[std::sync::Arc<rustdslib::storage::Block>]| {
                        Ok(vec![(*ins[0]).clone()])
                    }),
                )
            })
            .collect();
        rt2.submit_batch(batch);
        rt2.barrier()
    })?;
    rows.push((
        "task submit_batch+run x1000 (1x1)".into(),
        t_batch,
        format!(
            "{:.1} µs/task ({:.2}x vs serial)",
            t_batch * 1e3,
            t_serial / t_batch.max(1e-12)
        ),
    ));

    // ---- Refcount reclamation + fusion: rebinding pipeline residency ----
    // The 8 rebinding ops fold into ONE fused expression; the eager
    // pipeline would have produced 9 generations (36 MiB), the fused one
    // materializes once, in place over the dead source generation.
    let rt3 = Runtime::local(workers);
    let mut cur = creation::from_matrix(&rt3, &m, (128, 128))?;
    for _ in 0..8 {
        cur = cur.add_scalar(1.0)?; // deferred: extends the expression
    }
    let done = cur.force()?;
    done.runtime().barrier()?;
    let met = rt3.metrics();
    // Not a timing row: secs is NaN (null in the JSON artifact) so perf
    // tooling never mistakes MiB for seconds; the numbers live in the note.
    rows.push((
        "pipeline 8x add_scalar 1024² resident".into(),
        f64::NAN,
        format!(
            "{:.1} MiB peak of 36 MiB eager-equivalent; {} fused, {} in-place",
            met.peak_resident_bytes as f64 / (1024.0 * 1024.0),
            met.tasks_fused,
            met.inplace_hits
        ),
    ));

    // ---- L1/L2 via PJRT vs native ----
    if let Some(svc) = global() {
        let x = DenseMatrix::from_fn(64, 64, |_, _| rng.next_normal());
        let y = DenseMatrix::from_fn(64, 64, |_, _| rng.next_normal());
        let z = DenseMatrix::zeros(64, 64);
        let t = time(reps * 10, || exec::gemm_acc(svc, &x, &y, &z).map(|_| ()))?;
        let fl = 2.0 * 64f64.powi(3) / 1e9;
        rows.push(("pjrt gemm_64".into(), t, format!("{:.2} GFLOP/s", fl / t)));

        let x128 = DenseMatrix::from_fn(128, 128, |_, _| rng.next_normal());
        let y128 = DenseMatrix::from_fn(128, 128, |_, _| rng.next_normal());
        let z128 = DenseMatrix::zeros(128, 128);
        let t = time(reps * 10, || exec::gemm_acc(svc, &x128, &y128, &z128).map(|_| ()))?;
        let fl = 2.0 * 128f64.powi(3) / 1e9;
        rows.push(("pjrt gemm_128".into(), t, format!("{:.2} GFLOP/s", fl / t)));

        let t = time(reps * 10, || {
            x.matmul(&y).map(|_| ())
        })?;
        let fl = 2.0 * 64f64.powi(3) / 1e9;
        rows.push(("native matmul 64³".into(), t, format!("{:.2} GFLOP/s", fl / t)));

        let centers = DenseMatrix::from_fn(8, 64, |_, _| rng.next_normal());
        let t = time(reps * 10, || {
            exec::kmeans_assign(svc, &x, &centers).map(|_| ())
        })?;
        rows.push(("pjrt kmeans_64 (fused)".into(), t, format!("{:.0} µs", t * 1e6)));

        let mu = DenseMatrix::zeros(1, 64);
        let is = DenseMatrix::full(1, 64, 1.0);
        let t = time(reps * 10, || exec::standardize(svc, &x, &mu, &is).map(|_| ()))?;
        rows.push(("pjrt standardize_64".into(), t, format!("{:.0} µs", t * 1e6)));
    } else {
        rows.push(("pjrt".into(), f64::NAN, "artifacts not built".into()));
    }

    println!("{:<40} {:>12} {:>22}", "op", "secs/iter", "rate");
    println!("{}", "-".repeat(76));
    for (name, secs, rate) in &rows {
        println!("{name:<40} {secs:>12.6} {rate:>22}");
    }
    // Machine-readable residency/eviction/fusion counters.
    println!(
        "\npipeline-metrics: {}",
        rustdslib::bench::report::metrics_json(&met)
    );
    // Full machine-readable dump — CI uploads this as the BENCH_hotpath.json
    // artifact so the perf trajectory is tracked across PRs.
    if let Some(path) = args.get("json") {
        let json = rustdslib::bench::report::bench_rows_json(&rows, &met);
        std::fs::write(path, json)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
