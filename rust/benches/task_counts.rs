//! EXP-TASKS (paper §4.3/§5 task-complexity claims): measured task counts
//! vs partition count N, asserted against the paper's formulas:
//!
//!   transpose:  Dataset N²+N      vs ds-array N
//!   shuffle:    Dataset N·min(N,S)+N  vs ds-array 2N  (N²+N w/o collections)
//!
//! Plus the plan-layer rows: the same KMeans/ALS fits at optimizer `off`
//! vs `full` must produce bit-identical models from strictly fewer
//! submitted tasks (composed reduce tails).

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::dsarray::creation;
use rustdslib::estimators::als::{Als, AlsConfig};
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::plan::Level;
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::cli::Args;
use rustdslib::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = Config::resolve(&args)?;
    let ns = args.get_usize_list("n", &[8, 16, 32, 64, 128, 256]);
    let rows = experiments::task_count_table(&cfg, &ns)?;
    println!(
        "{:>5} | {:>12} {:>10} | {:>14} {:>10} {:>12}",
        "N", "D transpose", "A transpose", "D shuffle", "A shuffle", "A sh(nocoll)"
    );
    println!("{}", "-".repeat(74));
    let s = 4; // rows per subset in this workload
    for (n, d_tr, a_tr, d_sh, a_sh, a_shn) in rows {
        println!(
            "{n:>5} | {d_tr:>12} {a_tr:>10} | {d_sh:>14} {a_sh:>10} {a_shn:>12}"
        );
        assert_eq!(d_tr, (n * n + n) as u64);
        assert_eq!(a_tr, n as u64);
        assert_eq!(d_sh, (n * n.min(s) + n) as u64);
        assert_eq!(a_sh, 2 * n as u64);
        assert_eq!(a_shn, (n * n + n) as u64);
    }
    println!("\nall counts match the paper's formulas (N²+N vs N; N·min(N,S)+N vs 2N)");

    // ---- Plan-layer task counts: optimizer off vs full ----
    let mut rng = Xoshiro256::seed_from_u64(3);
    let km_m = DenseMatrix::from_fn(96, 8, |_, _| rng.next_normal());
    let als_m = DenseMatrix::from_fn(48, 32, |_, _| rng.next_normal());
    let fit = |level: Level| -> Result<(DenseMatrix, DenseMatrix, u64)> {
        let rt = Runtime::builder().workers(2).optimizer(level).build()?;
        let x = creation::from_matrix(&rt, &km_m, (16, 8))?;
        let mut km = KMeans::new(KMeansConfig {
            k: 3,
            max_iter: 6,
            tol: 1e-9,
            seed: 5,
        });
        km.fit_dsarray(&x)?;
        let r = creation::from_matrix(&rt, &als_m, (12, 8))?;
        let mut als = Als::new(AlsConfig {
            d: 4,
            lambda: 0.1,
            max_iter: 3,
            seed: 9,
        });
        als.fit_dsarray(&r)?;
        Ok((km.centers.unwrap(), als.u.unwrap(), rt.metrics().total_tasks()))
    };
    let (c_off, u_off, t_off) = fit(Level::Off)?;
    let (c_full, u_full, t_full) = fit(Level::Full)?;
    println!("\n{:>24} | {:>9} {:>9} {:>7}", "optimizer tasks", "off", "full", "saved");
    println!(
        "{:>24} | {t_off:>9} {t_full:>9} {:>7}",
        "kmeans+als fits",
        t_off.saturating_sub(t_full)
    );
    assert_eq!(c_full, c_off, "KMeans centroids must be bit-identical across levels");
    assert_eq!(u_full, u_off, "ALS factors must be bit-identical across levels");
    assert!(
        t_full < t_off,
        "optimizer full must submit strictly fewer tasks ({t_full} vs {t_off})"
    );
    println!("optimizer full is bit-identical with {} fewer tasks", t_off - t_full);
    Ok(())
}
