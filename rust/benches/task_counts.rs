//! EXP-TASKS (paper §4.3/§5 task-complexity claims): measured task counts
//! vs partition count N, asserted against the paper's formulas:
//!
//!   transpose:  Dataset N²+N      vs ds-array N
//!   shuffle:    Dataset N·min(N,S)+N  vs ds-array 2N  (N²+N w/o collections)

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = Config::resolve(&args)?;
    let ns = args.get_usize_list("n", &[8, 16, 32, 64, 128, 256]);
    let rows = experiments::task_count_table(&cfg, &ns)?;
    println!(
        "{:>5} | {:>12} {:>10} | {:>14} {:>10} {:>12}",
        "N", "D transpose", "A transpose", "D shuffle", "A shuffle", "A sh(nocoll)"
    );
    println!("{}", "-".repeat(74));
    let s = 4; // rows per subset in this workload
    for (n, d_tr, a_tr, d_sh, a_sh, a_shn) in rows {
        println!(
            "{n:>5} | {d_tr:>12} {a_tr:>10} | {d_sh:>14} {a_sh:>10} {a_shn:>12}"
        );
        assert_eq!(d_tr, (n * n + n) as u64);
        assert_eq!(a_tr, n as u64);
        assert_eq!(d_sh, (n * n.min(s) + n) as u64);
        assert_eq!(a_sh, 2 * n as u64);
        assert_eq!(a_shn, (n * n + n) as u64);
    }
    println!("\nall counts match the paper's formulas (N²+N vs N; N·min(N,S)+N vs 2N)");
    Ok(())
}
