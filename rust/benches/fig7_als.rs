//! EXP-ALS (paper Fig 7): ALS on Netflix-shape ratings; Dataset (192
//! Subsets + transposed copy) vs ds-array (192×192 blocks, direct column
//! access), on the simulated cluster.
//!
//! Usage: cargo bench --bench fig7_als [-- --cores ... --grid 192 --iters 10]

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::resolve(&args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768];
    }
    let grid = args.get_usize("grid", 192);
    let iters = args.get_usize("iters", 10);
    let s = experiments::fig7_als(&cfg, grid, iters)?;
    print!("{}", s.render());
    println!(
        "paper shape: Dataset competitive at few cores; ds-array faster at scale\n\
         (no transpose copy; overhead of {0}x{0} = {1} blocks is the price)",
        grid,
        grid * grid
    );
    Ok(())
}
