//! EXP-SH (paper Fig 8): weak-scaling pseudo-shuffle, 300 rows × 2 cols per
//! core; Dataset (N·min(N,S)+N tasks) vs ds-array (2N via collections).
//!
//! Usage: cargo bench --bench fig8_shuffle [-- --cores 48,...,1536]

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::resolve(&args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768, 1536];
    }
    let s = experiments::fig8_shuffle(&cfg)?;
    print!("{}", s.render());
    if let Some(p) = s.points.last() {
        if let Some(d) = p.dataset_s {
            println!(
                "improvement at {} cores: {:.1}% (paper: ~60%)",
                p.cores,
                100.0 * (1.0 - p.dsarray_s / d)
            );
        }
    }
    Ok(())
}
