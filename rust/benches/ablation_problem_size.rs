//! ABL-SIZE (paper §5.2, closing remark): "Experiments with a bigger
//! problem yield better scalability results for ds-arrays, but are
//! intractable when using Datasets". Sweep the transpose problem size and
//! report the ds-array strong-scaling efficiency at each size (and the
//! projected Dataset task count that makes it intractable).
//!
//! Usage: cargo bench --bench ablation_problem_size [-- --cores 48,768]

use anyhow::Result;
use rustdslib::config::Config;
use rustdslib::dsarray::creation;
use rustdslib::tasking::Runtime;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = Config::resolve(&args)?;
    let (lo, hi) = (48usize, 768usize);
    // Problem scale multipliers over the paper's 1536-partition base.
    println!(
        "{:>6} | {:>10} | {:>12} | {:>12} | {:>10} | {:>16}",
        "scale", "partitions", "t@48 (s)", "t@768 (s)", "speedup", "Dataset tasks"
    );
    println!("{}", "-".repeat(82));
    for scale in [1usize, 4, 16, 64] {
        // Bigger problem at fixed partitioning: each of the 1536 block-rows
        // carries `scale`× more data, so per-task work grows while the
        // master cost stays constant — exactly the regime the paper's
        // remark describes.
        let parts = 1536;
        let rows_per = 30 * scale;
        let rows = parts * rows_per;
        let cols = 46_080;
        let run = |cores: usize| -> Result<f64> {
            let rt = Runtime::sim(cfg.sim_at(cores));
            let a = creation::phantom(&rt, (rows, cols), (rows_per, cols), None)?;
            a.transpose()?;
            Ok(rt.run_sim()?.makespan_s)
        };
        let t_lo = run(lo)?;
        let t_hi = run(hi)?;
        let dataset_tasks = parts as u64 * parts as u64 + parts as u64;
        println!(
            "{scale:>5}x | {parts:>10} | {t_lo:>12.2} | {t_hi:>12.2} | {:>10.2} | {dataset_tasks:>16}",
            t_lo / t_hi
        );
    }
    println!(
        "\nds-array transpose scalability improves with problem size (compute begins\n\
         to amortize the master), while the Dataset version stays intractable at\n\
         any size (2.36M master-serialized tasks — paper §5.2's closing remark)"
    );
    Ok(())
}
