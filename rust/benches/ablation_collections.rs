//! ABL-COLL (paper §4.3): what the COLLECTION_IN/OUT runtime feature is
//! worth — ds-array shuffle with collections (2N tasks) vs the same
//! operation restricted to bounded-arity outputs (N²+N tasks).
//!
//! Usage: cargo bench --bench ablation_collections [-- --cores 48,...]

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::resolve(&args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768];
    }
    let rows = experiments::ablation_collections(&cfg)?;
    println!(
        "{:>6} | {:>14} {:>10} | {:>16} {:>10} | {:>8}",
        "cores", "with coll (s)", "tasks", "without coll (s)", "tasks", "speedup"
    );
    println!("{}", "-".repeat(78));
    for (cores, with_t, wo_t, with_tasks, wo_tasks) in rows {
        println!(
            "{cores:>6} | {with_t:>14.2} {with_tasks:>10} | {wo_t:>16.2} {wo_tasks:>10} | {:>8.2}",
            wo_t / with_t
        );
    }
    println!("\ncollections turn N²+N shuffle tasks into 2N (paper §4.3)");
    Ok(())
}
