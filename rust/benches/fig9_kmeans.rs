//! EXP-KM (paper Fig 9): strong-scaling K-means, ~50M × 1000, 1536
//! partitions — the control experiment: Dataset and ds-array curves must
//! overlap (the algorithm gains nothing from two-axis blocking).
//!
//! Usage: cargo bench --bench fig9_kmeans [-- --cores ... --iters 5]

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::resolve(&args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768, 1536];
    }
    let iters = args.get_usize("iters", 5);
    let s = experiments::fig9_kmeans(&cfg, iters)?;
    print!("{}", s.render());
    // Control check: max relative difference across points.
    let mut worst: f64 = 0.0;
    for p in &s.points {
        if let Some(d) = p.dataset_s {
            worst = worst.max((d - p.dsarray_s).abs() / d);
        }
    }
    println!("max |Dataset - ds-array| / Dataset = {:.1}% (paper: 'no significant difference')",
             100.0 * worst);
    Ok(())
}
