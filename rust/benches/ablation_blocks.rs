//! ABL-BLK (paper §5.3 discussion): ALS block-grid ablation. Finer grids
//! buy direct column access but multiply partitions (192×192 = 36 864
//! blocks), whose handling "can add up to minutes over the whole
//! execution" — this sweep quantifies that trade-off.
//!
//! Usage: cargo bench --bench ablation_blocks [-- --grids 48,96,192 --iters 3]

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = Config::resolve(&args)?;
    let grids = args.get_usize_list("grids", &[24, 48, 96, 192]);
    let iters = args.get_usize("iters", 3);
    let rows = experiments::ablation_blocks(&cfg, &grids, iters)?;
    let cores = *cfg.sim_cores.last().unwrap_or(&768);
    println!(
        "ALS (Netflix shape, {iters} iters) at {cores} simulated cores:\n\
         {:>6} | {:>10} | {:>12} | {:>10}",
        "grid", "blocks", "time (s)", "tasks"
    );
    println!("{}", "-".repeat(48));
    for (g, t, tasks) in rows {
        println!("{g:>6} | {:>10} | {t:>12.2} | {tasks:>10}", g * g);
    }
    Ok(())
}
