//! EXP-T (paper Fig 6): transpose strong + weak scaling, Datasets vs
//! ds-arrays, on the simulated MareNostrum cluster.
//!
//! Usage: cargo bench --bench fig6_transpose [-- --cores 48,96,... --strong|--weak]

use anyhow::Result;
use rustdslib::bench::experiments;
use rustdslib::config::Config;
use rustdslib::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::resolve(&args)?;
    if args.get("cores").is_none() {
        cfg.sim_cores = vec![48, 96, 192, 384, 768];
    }
    let which = (args.flag("strong"), args.flag("weak"));

    if which.0 || !which.1 {
        // Paper: Dataset strong-scaling points go missing at high core
        // counts ("memory issues due to handling a large number of tasks");
        // we run them all but report n.a. past the same point.
        let cap = args.get_usize("dataset-core-cap", 768);
        let s = experiments::fig6_strong(&cfg, cap)?;
        print!("{}", s.render());
        if let Some(r) = s.max_reduction_pct() {
            println!("max reduction: {r:.1}% (paper: up to 99%, 4.5h -> 7s)");
        }
    }
    if which.1 || !which.0 {
        let s = experiments::fig6_weak(&cfg)?;
        print!("{}", s.render());
        if let Some(r) = s.max_reduction_pct() {
            println!("max reduction: {r:.1}% (paper: 1.5h -> 14s at 768 cores)");
        }
    }
    Ok(())
}
