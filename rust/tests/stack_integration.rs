//! Cross-module integration: ds-array pipelines over the real executor,
//! Dataset↔ds-array agreement, estimator composition, sim/local graph
//! equivalence, and config plumbing.

use rustdslib::bench::workloads;
use rustdslib::config::Config;
use rustdslib::dataset::Dataset;
use rustdslib::dsarray::creation;
use rustdslib::estimators::als::{Als, AlsConfig};
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::{Estimator, LinearRegression, Pca, StandardScaler};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::{Runtime, SimConfig};
use rustdslib::util::rng::Xoshiro256;

#[test]
fn scaler_then_kmeans_pipeline() {
    let rt = Runtime::local(2);
    let (data, truth) = workloads::blobs(600, 24, 4, 0.6, 1);
    let x = creation::from_matrix(&rt, &data, (64, 24)).unwrap();
    let mut scaler = StandardScaler::default();
    let xs = scaler.fit_transform(&x).unwrap();
    let mut km = KMeans::new(KMeansConfig {
        k: 4,
        max_iter: 30,
        tol: 1e-6,
        seed: 3,
    });
    km.fit(&xs, None).unwrap();
    let pred = km.predict(&xs).unwrap().collect().unwrap();
    // Purity of majority assignment.
    let mut table = vec![vec![0usize; 4]; 4];
    for (i, &t) in truth.iter().enumerate() {
        table[t][pred.get(i, 0) as usize] += 1;
    }
    let purity: usize = table.iter().map(|r| *r.iter().max().unwrap()).sum();
    assert!(purity >= 570, "purity {purity}/600");
}

#[test]
fn pca_then_linreg_pipeline() {
    // y depends on the dominant direction only: PCA(1) should retain it.
    let rt = Runtime::local(2);
    let mut rng = Xoshiro256::seed_from_u64(4);
    let n = 256;
    let mut x = DenseMatrix::zeros(n, 6);
    let mut y = DenseMatrix::zeros(n, 1);
    for i in 0..n {
        let t = rng.next_normal() * 4.0;
        for j in 0..6 {
            let dir = if j < 3 { 1.0 } else { -1.0 };
            x.set(i, j, t * dir * 0.4 + rng.next_normal() * 0.2);
        }
        y.set(i, 0, 2.0 * t + 1.0 + rng.next_normal() * 0.1);
    }
    let xd = creation::from_matrix(&rt, &x, (64, 6)).unwrap();
    let yd = creation::from_matrix(&rt, &y, (64, 1)).unwrap();

    let mut pca = Pca::new(1);
    pca.fit(&xd, None).unwrap();
    let proj = pca.transform(&xd).unwrap();
    // LinReg on the single PCA feature (same runtime chain).
    let proj = proj.rechunk((64, 1)).unwrap();
    let mut lr = LinearRegression::default();
    lr.fit(&proj, Some(&yd)).unwrap();
    let r2 = lr.score(&proj, &yd).unwrap();
    assert!(r2 > 0.97, "R² {r2}");
}

#[test]
fn netflix_like_als_end_to_end() {
    let rt = Runtime::local(2);
    let ratings = workloads::netflix_like_csr(120, 600, 4000, 2).unwrap();
    let x = creation::from_csr(&rt, &ratings, (40, 150)).unwrap();
    assert!(x.is_sparse());
    let mut als = Als::new(AlsConfig {
        d: 8,
        lambda: 0.1,
        max_iter: 6,
        seed: 5,
    });
    als.fit_dsarray(&x).unwrap();
    // Observed cells predicted clearly above unobserved.
    let rec = als.reconstruct().unwrap();
    let dense = ratings.to_dense();
    let (mut on, mut non) = (0.0f64, 0usize);
    let (mut off, mut noff) = (0.0f64, 0usize);
    for i in 0..120 {
        for j in 0..600 {
            if dense.get(i, j) > 0.0 {
                on += rec.get(i, j) as f64;
                non += 1;
            } else {
                off += rec.get(i, j) as f64;
                noff += 1;
            }
        }
    }
    assert!(on / non as f64 > 3.0 * (off / noff as f64).abs().max(0.02));
}

#[test]
fn dataset_and_dsarray_transpose_agree_on_data() {
    let rt = Runtime::local(2);
    let m = DenseMatrix::from_fn(24, 24, |i, j| (i * 24 + j) as f32);
    let ds = Dataset::from_matrix(&rt, &m, None, 4).unwrap();
    let da = creation::from_matrix(&rt, &m, (6, 24)).unwrap();
    let t_ds = ds.transpose().unwrap().collect_samples().unwrap();
    let t_da = da.transpose().unwrap().collect().unwrap();
    assert_eq!(t_ds, t_da);
    assert_eq!(t_ds, m.transpose());
}

#[test]
fn sim_and_local_build_identical_graph_shapes() {
    // The same library code must emit the same task multiset under both
    // executors — the property that makes the DES results trustworthy.
    let build = |rt: &Runtime| {
        let a = creation::random(rt, (96, 48), (16, 16), 3).unwrap();
        let t = a.transpose().unwrap();
        let _ = t.sum_axis(0).unwrap();
        let _ = a.shuffle_rows(1).unwrap();
        let _ = a
            .matmul(&creation::random(rt, (48, 32), (16, 16), 4).unwrap())
            .unwrap();
    };
    let local = Runtime::local(2);
    build(&local);
    local.barrier().unwrap();
    let sim = Runtime::sim(SimConfig::with_workers(4));
    build(&sim);
    let ml = local.metrics();
    let ms = sim.metrics();
    assert_eq!(ml.tasks_by_op, ms.tasks_by_op);
    assert_eq!(ml.read_edges, ms.read_edges);
    assert_eq!(ml.write_edges, ms.write_edges);
    let report = sim.run_sim().unwrap();
    assert_eq!(report.tasks_executed as u64, ms.total_tasks());
}

#[test]
fn config_file_drives_simulation() {
    let dir = std::env::temp_dir();
    let p = dir.join(format!("itest_cfg_{}.toml", std::process::id()));
    std::fs::write(
        &p,
        "sim_cores = [4]\n[sim]\nsched_task_s = 0.1\ncore_scale = 1e12\nper_input_s = 0.0\nsched_edge_s = 0.0\ntask_overhead_s = 0.0\n",
    )
    .unwrap();
    let cfg = Config::from_file(&p).unwrap();
    let rt = Runtime::sim(cfg.sim_at(4));
    let a = creation::phantom(&rt, (40, 8), (10, 8), None).unwrap();
    a.transpose().unwrap();
    let r = rt.run_sim().unwrap();
    // 4 transpose tasks × 0.1s serialized master ≈ 0.4s (+ compute ~0).
    assert!(
        r.makespan_s >= 0.4 && r.makespan_s < 0.6,
        "makespan {}",
        r.makespan_s
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn csv_to_pipeline_round_trip() {
    // File -> ds-array -> ops -> collect, through the real loader tasks.
    let rt = Runtime::local(2);
    let m = DenseMatrix::from_fn(30, 10, |i, j| (i as f32) * 0.1 - j as f32);
    let p = std::env::temp_dir().join(format!("itest_data_{}.csv", std::process::id()));
    rustdslib::storage::io::write_csv(&p, &m, ',').unwrap();
    let a = creation::load_csv(&rt, &p, (30, 10), (8, 4), ',').unwrap();
    let s = a.add_scalar(1.0).unwrap().mul_scalar(2.0).unwrap();
    let got = s.collect().unwrap();
    assert_eq!(got, m.map(|x| (x + 1.0) * 2.0));
    std::fs::remove_file(&p).ok();
}

#[test]
fn kmeans_paper_workload_miniature_sim() {
    // Fig 9 miniature: compute-bound K-means should scale with cores.
    let cfg = Config::default();
    let mk = |cores: usize| {
        let rt = Runtime::sim(cfg.sim_at(cores));
        // 192 fat partitions (~0.6s compute each): compute >> overheads.
        let x = creation::phantom(&rt, (9_600_000, 100), (50_000, 100), None).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 50,
            max_iter: 3,
            tol: 0.0,
            seed: 1,
        });
        km.fit_dsarray(&x).unwrap();
        rt.run_sim().unwrap().makespan_s
    };
    let t48 = mk(48);
    let t96 = mk(96);
    assert!(t96 < t48, "compute-bound workload should scale: {t48} -> {t96}");
}
