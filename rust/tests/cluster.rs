//! Local-vs-cluster parity suite: the same workloads must produce
//! bit-identical results whether blocks live in the coordinator's memory
//! (local backend) or on ≥2 **separate worker processes** reached over TCP
//! (cluster backend). Workers here are real `dsarray worker` OS processes
//! spawned from the built CLI binary — this is the repo's first test in
//! which a block actually crosses a process boundary.
//!
//! Also covers the failure contract: a worker process killed mid-workload
//! must surface as a poisoned task naming the worker address and task —
//! never a hang.

use std::path::Path;
use std::process::Child;

use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::{Estimator, LinearRegression, Pca};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::cluster::spawn_worker_process;
use rustdslib::tasking::wire::{self, Request, Response, WorkerStat};
use rustdslib::tasking::{ClusterOptions, Runtime};
use rustdslib::util::rng::Xoshiro256;

/// A fleet of real worker processes; killed (and reaped) on drop.
struct Workers {
    children: Vec<Child>,
    addrs: Vec<String>,
}

impl Workers {
    fn spawn(n: usize, budget_bytes: Option<u64>) -> Self {
        // The library's spawn helper, pointed at the real CLI binary (a
        // test harness's current_exe is the test binary, not `dsarray`).
        let program = Path::new(env!("CARGO_BIN_EXE_dsarray"));
        let mut children = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..n {
            let (child, addr) =
                spawn_worker_process(program, budget_bytes).expect("spawn dsarray worker");
            children.push(child);
            addrs.push(addr);
        }
        Self { children, addrs }
    }

    fn runtime(&self) -> Runtime {
        Runtime::cluster(ClusterOptions::connect(self.addrs.clone()).with_threads(2)).unwrap()
    }

    fn stat(&self, i: usize) -> WorkerStat {
        let mut s = std::net::TcpStream::connect(&self.addrs[i]).unwrap();
        wire::write_request(&mut s, &Request::Stat).unwrap();
        match wire::read_response(&mut s).unwrap().0 {
            Response::Stat(st) => st,
            other => panic!("got {other:?}"),
        }
    }
}

impl Drop for Workers {
    fn drop(&mut self) {
        for c in &mut self.children {
            c.kill().ok();
            c.wait().ok();
        }
    }
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> DenseMatrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    DenseMatrix::from_fn(rows, cols, |_, _| rng.next_normal())
}

/// The acceptance scenario: a KMeans fit over 2 worker processes is
/// bit-identical to the local fit, with real bytes on the wire and the
/// locality scheduler visibly placing tasks where their inputs live.
#[test]
fn kmeans_parity_local_vs_cluster() {
    let m = random_matrix(96, 8, 11);
    let fit = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &m, (16, 8)).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 4,
            max_iter: 8,
            tol: 1e-9,
            seed: 5,
        });
        km.fit(&x, None).unwrap();
        (km.centers.unwrap(), km.inertia)
    };
    let (centers_local, inertia_local) = fit(&Runtime::local(2));

    let workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (centers_cluster, inertia_cluster) = fit(&rt);

    assert_eq!(centers_cluster, centers_local, "bit-for-bit centroid parity");
    assert_eq!(inertia_cluster, inertia_local);
    let met = rt.metrics();
    assert!(met.bytes_on_wire > 0, "blocks must actually cross the wire");
    assert!(met.locality_hits > 0, "placement must find co-located inputs");
    // Both worker processes really held blocks.
    assert!(workers.stat(0).blocks > 0);
    assert!(workers.stat(1).blocks > 0);
}

#[test]
fn pca_and_linreg_parity_local_vs_cluster() {
    let xm = random_matrix(96, 16, 44);
    let ym = random_matrix(96, 1, 45);
    let run = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &xm, (12, 16)).unwrap();
        let mut pca = Pca::new(4);
        pca.fit(&x, None).unwrap();
        let y = creation::from_matrix(rt, &ym, (12, 1)).unwrap();
        let mut lr = LinearRegression::new(1e-4, true);
        lr.fit(&x, Some(&y)).unwrap();
        (pca.components.unwrap(), lr.weights.unwrap(), lr.intercept)
    };
    let (comp_l, w_l, b_l) = run(&Runtime::local(2));
    let workers = Workers::spawn(2, None);
    let (comp_c, w_c, b_c) = run(&workers.runtime());
    assert_eq!(comp_c, comp_l, "PCA components parity");
    assert_eq!(w_c, w_l, "ridge weights parity");
    assert_eq!(b_c, b_l);
}

/// Per-worker memory budgets: a matmul whose working set exceeds every
/// worker's budget still matches the local result bit for bit, and the
/// worker-side spill counters prove the disk tier was exercised.
#[test]
fn spill_backed_matmul_parity_with_worker_budgets() {
    let ma = random_matrix(64, 64, 21);
    let mb = random_matrix(64, 64, 22);
    let run = |rt: &Runtime| {
        let a = creation::from_matrix(rt, &ma, (16, 16)).unwrap();
        let b = creation::from_matrix(rt, &mb, (16, 16)).unwrap();
        a.matmul(&b).unwrap().collect().unwrap()
    };
    let expect = run(&Runtime::local(2));
    // Each 16x16 f32 block is 1 KiB; 2 KiB budgets force worker spills.
    let workers = Workers::spawn(2, Some(2048));
    let got = run(&workers.runtime());
    assert_eq!(got, expect, "spill-backed cluster matmul must be bit-identical");
    let spilled = workers.stat(0).blocks_spilled + workers.stat(1).blocks_spilled;
    assert!(spilled > 0, "worker budgets must actually spill");
}

/// Fused elementwise chains and lazy views run unmodified on the cluster
/// backend: one fused task per block against remote inputs, view
/// materialization gathers across worker-held blocks.
#[test]
fn fused_chain_and_view_parity_local_vs_cluster() {
    let m = random_matrix(64, 64, 33);
    let run = |rt: &Runtime| {
        let a = creation::from_matrix(rt, &m, (8, 8)).unwrap();
        let fused = a
            .add_scalar(1.0)
            .unwrap()
            .mul_scalar(0.5)
            .unwrap()
            .add_scalar(-3.0)
            .unwrap()
            .collect()
            .unwrap();
        let view = a.slice(3, 61, 5, 50).unwrap(); // unaligned: lazy view
        assert!(view.is_view());
        let forced = view.force().unwrap().collect().unwrap();
        let metrics = rt.metrics();
        (fused, forced, metrics.tasks_for("dsarray.ew.fused"))
    };
    let (fused_l, view_l, n_fused_l) = run(&Runtime::local(2));
    let workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (fused_c, view_c, n_fused_c) = run(&rt);
    assert_eq!(fused_c, fused_l, "fused chain parity");
    assert_eq!(view_c, view_l, "forced view parity");
    // Identical graphs on both backends: the chain still collapses to one
    // fused task per block.
    assert_eq!(n_fused_c, n_fused_l);
    assert!(rt.metrics().bytes_on_wire > 0);
}

/// Kernel-layer parity: with the intra-block split threshold forced low
/// enough that the single-block gemm and pairwise-distance tasks split
/// into sub-range work items on the local backend, the cluster backend
/// (whose coordinator pool may or may not split) must still produce
/// bit-identical results — sub-task plans depend only on work size, and
/// every part keeps the same per-element accumulation order.
#[test]
fn kernel_split_parity_local_vs_cluster() {
    let ma = random_matrix(96, 64, 61);
    let mb = random_matrix(64, 80, 62);
    let prev = rustdslib::kernels::set_split_min(1024);
    let run = |rt: &Runtime| {
        // Single-block operands: the whole gemm is one fat task.
        let a = creation::from_matrix(rt, &ma, (96, 64)).unwrap();
        let b = creation::from_matrix(rt, &mb, (64, 80)).unwrap();
        let mm = a.matmul(&b).unwrap().collect().unwrap();
        let pd = a.pairwise_dist2(&a).unwrap().collect().unwrap();
        (mm, pd, rt.metrics().subtasks_spawned)
    };
    let local_rt = Runtime::local(4);
    let (mm_l, pd_l, subs_l) = run(&local_rt);
    let workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (mm_c, pd_c, _) = run(&rt);
    rustdslib::kernels::set_split_min(prev);
    assert_eq!(mm_c, mm_l, "split gemm parity local vs cluster");
    assert_eq!(pd_c, pd_l, "pairwise dist2 parity local vs cluster");
    assert!(subs_l > 0, "local fat tasks must have split into sub-tasks");
    assert!(rt.metrics().bytes_on_wire > 0);
}

/// A worker process dying mid-workload must poison the runtime with the
/// worker address and the failing task's name — and every subsequent
/// synchronization must error immediately instead of hanging (mirrors the
/// PR-1 fix that removed the silent input-resolution swallow).
#[test]
fn killed_worker_poisons_with_address_and_task_name() {
    let mut workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let m = random_matrix(32, 32, 7);
    let a = creation::from_matrix(&rt, &m, (8, 8)).unwrap();
    rt.barrier().unwrap();
    // Both workers hold half of the 16 blocks.
    assert!(workers.stat(0).blocks > 0 && workers.stat(1).blocks > 0);

    // Kill worker 0 mid-cluster. Tasks over its blocks must fail loudly.
    workers.children[0].kill().unwrap();
    workers.children[0].wait().unwrap();

    let err = a
        .add_scalar(1.0)
        .unwrap()
        .collect()
        .expect_err("reading blocks of a dead worker must fail")
        .to_string();
    assert!(err.contains("task `"), "error should name the task: {err}");
    assert!(
        err.contains(&workers.addrs[0]),
        "error should name the dead worker {}: {err}",
        workers.addrs[0]
    );
    // Poisoned, not hung: barriers and fresh waits fail fast.
    let b_err = rt.barrier().expect_err("barrier must observe the poison");
    assert!(b_err.to_string().contains("poisoned"), "{b_err}");
}
