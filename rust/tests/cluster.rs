//! Local-vs-cluster parity suite: the same workloads must produce
//! bit-identical results whether blocks live in the coordinator's memory
//! (local backend) or on ≥2 **separate worker processes** reached over TCP
//! (cluster backend). Workers here are real `dsarray worker` OS processes
//! spawned from the built CLI binary — this is the repo's first test in
//! which a block actually crosses a process boundary.
//!
//! Also covers the failure contract: a worker process killed mid-workload
//! is **recovered from** — the lineage walk replays the lost sub-graph on
//! survivors and results stay bit-identical — while `--no-recovery`
//! restores the old poison-with-address-and-task contract. A seeded chaos
//! suite drives both through deterministic `FaultPlan`s, and the elastic
//! membership path is exercised end-to-end by a real `dsarray worker
//! --join` process enrolling into a running fleet.

use std::path::Path;
use std::process::Child;

use rustdslib::bench::report;
use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::{Estimator, LinearRegression, Pca};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::cluster::spawn_worker_process_with;
use rustdslib::tasking::wire::{self, Request, Response, WorkerStat};
use rustdslib::tasking::{ClusterOptions, FaultPlan, Runtime};
use rustdslib::util::rng::Xoshiro256;

/// A fleet of real worker processes; killed (and reaped) on drop.
struct Workers {
    children: Vec<Child>,
    addrs: Vec<String>,
}

impl Workers {
    fn spawn(n: usize, budget_bytes: Option<u64>) -> Self {
        Self::spawn_with_faults(n, budget_bytes, &FaultPlan::none(n))
    }

    /// Spawn `n` workers, each carrying its slice of a deterministic fault
    /// plan (`--fault-plan die@7` etc.); an empty slice runs fault-free.
    fn spawn_with_faults(n: usize, budget_bytes: Option<u64>, plan: &FaultPlan) -> Self {
        // The library's spawn helper, pointed at the real CLI binary (a
        // test harness's current_exe is the test binary, not `dsarray`).
        let program = Path::new(env!("CARGO_BIN_EXE_dsarray"));
        let mut children = Vec::new();
        let mut addrs = Vec::new();
        for w in 0..n {
            let spec = plan.spec_for(w);
            let (child, addr) =
                spawn_worker_process_with(program, budget_bytes, Some(spec.as_str()))
                    .expect("spawn dsarray worker");
            children.push(child);
            addrs.push(addr);
        }
        Self { children, addrs }
    }

    fn runtime(&self) -> Runtime {
        Runtime::cluster(ClusterOptions {
            addrs: self.addrs.clone(),
            ..Default::default()
        })
        .unwrap()
    }

    fn stat(&self, i: usize) -> WorkerStat {
        let mut s = std::net::TcpStream::connect(&self.addrs[i]).unwrap();
        wire::write_request(&mut s, &Request::Stat).unwrap();
        match wire::read_response(&mut s).unwrap().0 {
            Response::Stat(st) => st,
            other => panic!("got {other:?}"),
        }
    }
}

impl Drop for Workers {
    fn drop(&mut self) {
        for c in &mut self.children {
            // Children killed mid-test (SIGKILL scenarios, injected `die`
            // faults) are already dead: just reap them. Only still-running
            // children need the kill; `.ok()`s keep a worker corpse from
            // masking the panic that actually failed the test.
            match c.try_wait() {
                Ok(Some(_)) => {}
                _ => {
                    c.kill().ok();
                    c.wait().ok();
                }
            }
        }
    }
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> DenseMatrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    DenseMatrix::from_fn(rows, cols, |_, _| rng.next_normal())
}

/// The acceptance scenario: a KMeans fit over 2 worker processes is
/// bit-identical to the local fit, with real bytes on the wire and the
/// locality scheduler visibly placing tasks where their inputs live.
#[test]
fn kmeans_parity_local_vs_cluster() {
    let m = random_matrix(96, 8, 11);
    let fit = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &m, (16, 8)).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 4,
            max_iter: 8,
            tol: 1e-9,
            seed: 5,
        });
        km.fit(&x, None).unwrap();
        (km.centers.unwrap(), km.inertia)
    };
    let (centers_local, inertia_local) = fit(&Runtime::local(2));

    let workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (centers_cluster, inertia_cluster) = fit(&rt);

    assert_eq!(centers_cluster, centers_local, "bit-for-bit centroid parity");
    assert_eq!(inertia_cluster, inertia_local);
    let met = rt.metrics();
    assert!(met.bytes_on_wire > 0, "blocks must actually cross the wire");
    assert!(met.locality_hits > 0, "placement must find co-located inputs");
    // Both worker processes really held blocks.
    assert!(workers.stat(0).blocks > 0);
    assert!(workers.stat(1).blocks > 0);
}

#[test]
fn pca_and_linreg_parity_local_vs_cluster() {
    let xm = random_matrix(96, 16, 44);
    let ym = random_matrix(96, 1, 45);
    let run = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &xm, (12, 16)).unwrap();
        let mut pca = Pca::new(4);
        pca.fit(&x, None).unwrap();
        let y = creation::from_matrix(rt, &ym, (12, 1)).unwrap();
        let mut lr = LinearRegression::new(1e-4, true);
        lr.fit(&x, Some(&y)).unwrap();
        (pca.components.unwrap(), lr.weights.unwrap(), lr.intercept)
    };
    let (comp_l, w_l, b_l) = run(&Runtime::local(2));
    let workers = Workers::spawn(2, None);
    let (comp_c, w_c, b_c) = run(&workers.runtime());
    assert_eq!(comp_c, comp_l, "PCA components parity");
    assert_eq!(w_c, w_l, "ridge weights parity");
    assert_eq!(b_c, b_l);
}

/// Per-worker memory budgets: a matmul whose working set exceeds every
/// worker's budget still matches the local result bit for bit, and the
/// worker-side spill counters prove the disk tier was exercised.
#[test]
fn spill_backed_matmul_parity_with_worker_budgets() {
    let ma = random_matrix(64, 64, 21);
    let mb = random_matrix(64, 64, 22);
    let run = |rt: &Runtime| {
        let a = creation::from_matrix(rt, &ma, (16, 16)).unwrap();
        let b = creation::from_matrix(rt, &mb, (16, 16)).unwrap();
        a.matmul(&b).unwrap().collect().unwrap()
    };
    let expect = run(&Runtime::local(2));
    // Each 16x16 f32 block is 1 KiB; 2 KiB budgets force worker spills.
    let workers = Workers::spawn(2, Some(2048));
    let got = run(&workers.runtime());
    assert_eq!(got, expect, "spill-backed cluster matmul must be bit-identical");
    let spilled = workers.stat(0).blocks_spilled + workers.stat(1).blocks_spilled;
    assert!(spilled > 0, "worker budgets must actually spill");
}

/// Fused elementwise chains and lazy views run unmodified on the cluster
/// backend: one fused task per block against remote inputs, view
/// materialization gathers across worker-held blocks.
#[test]
fn fused_chain_and_view_parity_local_vs_cluster() {
    let m = random_matrix(64, 64, 33);
    let run = |rt: &Runtime| {
        let a = creation::from_matrix(rt, &m, (8, 8)).unwrap();
        let fused = a
            .add_scalar(1.0)
            .unwrap()
            .mul_scalar(0.5)
            .unwrap()
            .add_scalar(-3.0)
            .unwrap()
            .collect()
            .unwrap();
        let view = a.slice(3, 61, 5, 50).unwrap(); // unaligned: lazy view
        assert!(view.is_view());
        let forced = view.force().unwrap().collect().unwrap();
        let metrics = rt.metrics();
        (fused, forced, metrics.tasks_for("dsarray.ew.fused"))
    };
    let (fused_l, view_l, n_fused_l) = run(&Runtime::local(2));
    let workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (fused_c, view_c, n_fused_c) = run(&rt);
    assert_eq!(fused_c, fused_l, "fused chain parity");
    assert_eq!(view_c, view_l, "forced view parity");
    // Identical graphs on both backends: the chain still collapses to one
    // fused task per block.
    assert_eq!(n_fused_c, n_fused_l);
    assert!(rt.metrics().bytes_on_wire > 0);
}

/// Kernel-layer parity: with the intra-block split threshold forced low
/// enough that the single-block gemm and pairwise-distance tasks split
/// into sub-range work items on the local backend, the cluster backend
/// (whose coordinator pool may or may not split) must still produce
/// bit-identical results — sub-task plans depend only on work size, and
/// every part keeps the same per-element accumulation order.
#[test]
fn kernel_split_parity_local_vs_cluster() {
    let ma = random_matrix(96, 64, 61);
    let mb = random_matrix(64, 80, 62);
    let prev = rustdslib::kernels::set_split_min(1024);
    let run = |rt: &Runtime| {
        // Single-block operands: the whole gemm is one fat task.
        let a = creation::from_matrix(rt, &ma, (96, 64)).unwrap();
        let b = creation::from_matrix(rt, &mb, (64, 80)).unwrap();
        let mm = a.matmul(&b).unwrap().collect().unwrap();
        let pd = a.pairwise_dist2(&a).unwrap().collect().unwrap();
        (mm, pd, rt.metrics().subtasks_spawned)
    };
    let local_rt = Runtime::local(4);
    let (mm_l, pd_l, subs_l) = run(&local_rt);
    let workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (mm_c, pd_c, _) = run(&rt);
    rustdslib::kernels::set_split_min(prev);
    assert_eq!(mm_c, mm_l, "split gemm parity local vs cluster");
    assert_eq!(pd_c, pd_l, "pairwise dist2 parity local vs cluster");
    assert!(subs_l > 0, "local fat tasks must have split into sub-tasks");
    assert!(rt.metrics().bytes_on_wire > 0);
}

/// The acceptance scenario for lineage recovery: SIGKILL one of two worker
/// processes mid-KMeans and the fit still completes **bit-identically** to
/// the local run — the coordinator replays the dead worker's lost
/// sub-graph on the survivor and re-loads roots from its journal. The
/// shifted input (`add_scalar` before the kill) guarantees produced — not
/// just root — blocks are lost, so `tasks_replayed` must be non-zero.
#[test]
fn killed_worker_recovers_bit_identically_mid_kmeans() {
    let m = random_matrix(32, 32, 7);
    let fit = |rt: &Runtime, kill: &mut dyn FnMut()| {
        let x = creation::from_matrix(rt, &m, (8, 8)).unwrap();
        let y = x.add_scalar(1.0).unwrap();
        rt.barrier().unwrap(); // all 16 shift tasks Done, outputs resident
        kill();
        let mut km = KMeans::new(KMeansConfig {
            k: 4,
            max_iter: 8,
            tol: 1e-9,
            seed: 5,
        });
        km.fit(&y, None).unwrap();
        (km.centers.unwrap(), km.inertia)
    };
    let (centers_local, inertia_local) = fit(&Runtime::local(2), &mut || {});

    let mut workers = Workers::spawn(2, None);
    let rt = workers.runtime();
    let (centers_cluster, inertia_cluster) = fit(&rt, &mut || {
        // Half the shifted blocks live here; mid-fit SIGKILL.
        workers.children[0].kill().unwrap();
        workers.children[0].wait().unwrap();
    });

    assert_eq!(centers_cluster, centers_local, "recovered fit must be bit-identical");
    assert_eq!(inertia_cluster, inertia_local);
    let met = rt.metrics();
    assert_eq!(met.workers_lost, 1, "exactly one worker death observed");
    assert!(met.tasks_replayed > 0, "lost shift tasks must be replayed, got 0");
    assert!(met.blocks_recovered > 0, "lost blocks must be re-materialized");
    // The counters flow through the emitted metrics line verbatim.
    let json = report::metrics_json(&met);
    assert!(json.contains("\"workers_lost\":1"), "{json}");
    assert!(json.contains("\"tasks_replayed\":"), "{json}");
    assert!(json.contains("\"blocks_recovered\":"), "{json}");
    assert!(json.contains("\"recovery_ms\":"), "{json}");
    // The survivor now holds everything the fit needed.
    assert!(workers.stat(1).blocks > 0);
}

/// With `--no-recovery` the old failure contract still holds: a worker
/// process dying mid-workload poisons the runtime with the worker address
/// and the failing task's name — and every subsequent synchronization
/// errors immediately instead of hanging (mirrors the PR-1 fix that
/// removed the silent input-resolution swallow).
#[test]
fn killed_worker_poisons_without_recovery() {
    let mut workers = Workers::spawn(2, None);
    let rt = Runtime::cluster(
        ClusterOptions {
            addrs: workers.addrs.clone(),
            recovery: false,
            ..Default::default()
        },
    )
    .unwrap();
    let m = random_matrix(32, 32, 7);
    let a = creation::from_matrix(&rt, &m, (8, 8)).unwrap();
    rt.barrier().unwrap();
    // Both workers hold half of the 16 blocks.
    assert!(workers.stat(0).blocks > 0 && workers.stat(1).blocks > 0);

    // Kill worker 0 mid-cluster. Tasks over its blocks must fail loudly.
    workers.children[0].kill().unwrap();
    workers.children[0].wait().unwrap();

    let err = a
        .add_scalar(1.0)
        .unwrap()
        .collect()
        .expect_err("reading blocks of a dead worker must fail")
        .to_string();
    assert!(err.contains("task `"), "error should name the task: {err}");
    assert!(
        err.contains(&workers.addrs[0]),
        "error should name the dead worker {}: {err}",
        workers.addrs[0]
    );
    assert!(err.contains("recovery is disabled"), "{err}");
    // Poisoned, not hung: barriers and fresh waits fail fast.
    let b_err = rt.barrier().expect_err("barrier must observe the poison");
    assert!(b_err.to_string().contains("poisoned"), "{b_err}");
}

/// Seeded chaos property test: for each seed, derive a deterministic
/// `FaultPlan` (which workers die or drop connections, and at which served
/// request), run a seed-selected workload on a 3-worker fleet under that
/// plan, and require the result to be bit-identical to the fault-free
/// local run. Failing seeds are reproducible: the panic names the exact
/// `DSARRAY_CHAOS_SEEDS=<seed>` rerun, and that env var (comma-separated)
/// also overrides the default seed set.
#[test]
fn chaos_seeded_fault_plans_stay_bit_identical() {
    let seeds: Vec<u64> = match std::env::var("DSARRAY_CHAOS_SEEDS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("bad DSARRAY_CHAOS_SEEDS entry"))
            .collect(),
        Err(_) => vec![101, 202, 303, 404, 505, 606, 707, 808],
    };
    for seed in seeds {
        let round = std::panic::catch_unwind(|| chaos_round(seed));
        if round.is_err() {
            panic!("chaos seed {seed} diverged; rerun with DSARRAY_CHAOS_SEEDS={seed}");
        }
    }
}

fn chaos_round(seed: u64) {
    let plan = FaultPlan::random(seed, 3);
    let ma = random_matrix(64, 64, seed ^ 0x9e37);
    let mb = random_matrix(64, 64, seed ^ 0x79b9);
    // Workload families rotate with the seed: lazy views, fused chains,
    // spill-backed matmul (2 KiB budgets), pairwise distances.
    let workload = (seed % 4) as usize;
    let run = |rt: &Runtime| -> DenseMatrix {
        let a = creation::from_matrix(rt, &ma, (16, 16)).unwrap();
        match workload {
            0 => a.slice(3, 61, 5, 50).unwrap().force().unwrap().collect().unwrap(),
            1 => a
                .add_scalar(1.0)
                .unwrap()
                .mul_scalar(0.5)
                .unwrap()
                .add_scalar(-3.0)
                .unwrap()
                .collect()
                .unwrap(),
            2 => {
                let b = creation::from_matrix(rt, &mb, (16, 16)).unwrap();
                a.matmul(&b).unwrap().collect().unwrap()
            }
            _ => a.pairwise_dist2(&a).unwrap().collect().unwrap(),
        }
    };
    let expect = run(&Runtime::local(2));
    let budget = if workload == 2 { Some(2048) } else { None };
    let workers = Workers::spawn_with_faults(3, budget, &plan);
    let rt = workers.runtime();
    let got = run(&rt);
    assert_eq!(got, expect, "chaos plan {plan:?} diverged from the fault-free local run");
}

/// Plan-layer parity on the cluster backend: KMeans, ALS, and PCA fits at
/// `Level::Off` and `Level::Full` (via the `Runtime::builder()` front
/// door) produce bit-identical models, while the optimizer strictly
/// shrinks `tasks_submitted` in the emitted metrics line — the composed
/// `kmeans.reduce_update` / `als.gram_reduce_ridge` tails and the CSE'd
/// PCA gram replace their eager task streams, never their bits.
#[test]
fn optimizer_parity_kmeans_als_pca_off_vs_full_on_cluster() {
    use rustdslib::estimators::als::AlsConfig;
    use rustdslib::estimators::Als;
    use rustdslib::plan::Level;

    let xm = random_matrix(64, 6, 91);
    let rm = random_matrix(24, 16, 92);
    let run = |level: Level| {
        let workers = Workers::spawn(2, None);
        let rt = Runtime::builder()
            .backend(rustdslib::config::Backend::Cluster)
            .cluster_addrs(workers.addrs.clone())
            .optimizer(level)
            .build()
            .unwrap();
        let x = creation::from_matrix(&rt, &xm, (16, 6)).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 3,
            max_iter: 6,
            tol: 1e-9,
            seed: 5,
        });
        km.fit(&x, None).unwrap();
        let mut pca = Pca::new(2);
        pca.fit(&x, None).unwrap();
        let r = creation::from_matrix(&rt, &rm, (6, 4)).unwrap();
        let mut als = Als::new(AlsConfig {
            d: 3,
            lambda: 0.05,
            max_iter: 3,
            seed: 9,
        });
        als.fit_dsarray(&r).unwrap();
        let json = report::metrics_json(&rt.metrics());
        (
            km.centers.unwrap(),
            km.inertia,
            pca.components.unwrap(),
            als.u.unwrap(),
            als.v.unwrap(),
            json,
        )
    };
    let (c_off, i_off, p_off, u_off, v_off, j_off) = run(Level::Off);
    let (c_full, i_full, p_full, u_full, v_full, j_full) = run(Level::Full);
    assert_eq!(c_full, c_off, "KMeans centroid parity across optimizer levels");
    assert_eq!(i_full, i_off, "KMeans inertia parity");
    assert_eq!(p_full, p_off, "PCA component parity");
    assert_eq!(u_full, u_off, "ALS U parity");
    assert_eq!(v_full, v_off, "ALS V parity");

    let submitted = |j: &str| {
        rustdslib::util::json::parse(j)
            .expect("metrics line parses")
            .get("tasks_submitted")
            .and_then(|v| v.as_f64())
            .expect("tasks_submitted present") as u64
    };
    let (s_off, s_full) = (submitted(&j_off), submitted(&j_full));
    assert!(
        s_full < s_off,
        "optimizer must strictly shrink tasks_submitted: {s_full} vs {s_off}"
    );
    assert!(j_full.contains("\"tasks_deduped\":"), "{j_full}");
    assert!(j_full.contains("\"blocks_prereleased\":"), "{j_full}");
}

/// The elasticity acceptance scenario with real OS processes: a second
/// `dsarray worker` started with `--join <control-addr>` enrolls itself
/// into a running single-worker fleet, and new work visibly spreads onto
/// it — non-zero per-worker task count in the metrics line, blocks held in
/// the joined process.
#[test]
fn joined_worker_process_receives_tasks() {
    use std::io::BufRead;

    let mut workers = Workers::spawn(1, None);
    let rt = workers.runtime();
    let control = rt.cluster_control_addr().expect("cluster runtimes expose a control address");

    let program = Path::new(env!("CARGO_BIN_EXE_dsarray"));
    let mut child = std::process::Command::new(program)
        .args(["worker", "--listen", "127.0.0.1:0", "--join", &control])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn joining dsarray worker");
    let mut lines = std::io::BufReader::new(child.stdout.take().unwrap()).lines();
    let listening = lines.next().expect("LISTENING line").unwrap();
    let joined_addr =
        listening.strip_prefix("LISTENING ").expect("LISTENING prefix").to_string();
    // Hand the child to the fleet's drop guard before any assert can panic.
    workers.children.push(child);
    workers.addrs.push(joined_addr);
    // `JOINED` is printed only after the coordinator acknowledged the
    // enroll, so once it appears the membership table already has slot 1.
    let joined = lines.next().expect("JOINED line").unwrap();
    assert_eq!(joined, format!("JOINED {control}"));
    assert_eq!(rt.metrics().workers_joined, 1);

    // New work spreads across both processes and stays correct.
    let m = random_matrix(64, 8, 77);
    let x = creation::from_matrix(&rt, &m, (8, 8)).unwrap();
    let got = x.add_scalar(1.0).unwrap().collect().unwrap();
    for i in [0usize, 31, 63] {
        assert_eq!(got.get(i, 3), m.get(i, 3) + 1.0);
    }
    let met = rt.metrics();
    assert_eq!(met.tasks_by_worker.len(), 2, "{:?}", met.tasks_by_worker);
    assert!(
        met.tasks_by_worker[1] > 0,
        "joined worker ran no tasks: {:?}",
        met.tasks_by_worker
    );
    assert!(workers.stat(1).blocks > 0, "joined worker holds no blocks");
    let json = report::metrics_json(&met);
    assert!(json.contains("\"workers_joined\":1"), "{json}");
    assert!(json.contains("\"tasks_by_worker\":["), "{json}");
}
