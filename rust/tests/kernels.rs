//! Property tests for the kernel layer: the detected (possibly SIMD)
//! vtable must be BIT-identical to the portable scalar table for every op
//! kind — including non-finite inputs, signed zeros, unaligned lengths
//! (`len % lanes != 0`) and empty blocks — and intra-block sub-task
//! splitting must never change results, whatever the worker count.

use std::sync::{Mutex, MutexGuard, OnceLock};

use rustdslib::dsarray::creation;
use rustdslib::kernels::{self, BinaryKind, UnaryKind};
use rustdslib::prop_assert;
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::prop::{check, Gen};

/// Serializes the tests that mutate the process-global split threshold
/// (integration tests in one binary run concurrently).
fn split_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Every unary kind, with generated payloads.
fn unary_kinds(g: &mut Gen) -> Vec<UnaryKind> {
    let s = g.f32_in(-3.0, 3.0);
    vec![
        UnaryKind::AddScalar(s),
        UnaryKind::MulScalar(s),
        UnaryKind::Pow(g.f32_in(-2.0, 2.0)),
        UnaryKind::Sqrt,
        UnaryKind::Abs,
        UnaryKind::Exp,
        UnaryKind::Neg,
    ]
}

const BINARY_KINDS: [BinaryKind; 5] = [
    BinaryKind::Add,
    BinaryKind::Sub,
    BinaryKind::Mul,
    BinaryKind::Div,
    BinaryKind::DivOrZero,
];

/// Random buffer with non-finite values and signed zeros mixed in.
fn noisy_vec(g: &mut Gen, len: usize) -> Vec<f32> {
    let mut xs = g.f32_vec(len, 4.0);
    for x in xs.iter_mut() {
        match g.usize_in(0, 19) {
            0 => *x = f32::NAN,
            1 => *x = f32::INFINITY,
            2 => *x = f32::NEG_INFINITY,
            3 => *x = 0.0,
            4 => *x = -0.0,
            _ => {}
        }
    }
    xs
}

#[test]
fn unary_kinds_bit_identical_scalar_vs_detected() {
    let (s, d) = (kernels::scalar(), kernels::detected());
    check("unary-bit-identical", |g| {
        // Lengths deliberately cross 0 and non-multiples of the lane count.
        let len = g.usize_in(0, 8 * g.size + 7);
        let xs = noisy_vec(g, len);
        for op in unary_kinds(g) {
            let mut a = xs.clone();
            let mut b = xs.clone();
            (s.unary)(op, &mut a);
            (d.unary)(op, &mut b);
            for i in 0..len {
                prop_assert!(
                    a[i].to_bits() == b[i].to_bits(),
                    "{op:?} diverged at {i} (len {len}): {} vs {} (x={})",
                    a[i],
                    b[i],
                    xs[i]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn binary_kinds_bit_identical_scalar_vs_detected() {
    let (s, d) = (kernels::scalar(), kernels::detected());
    check("binary-bit-identical", |g| {
        let len = g.usize_in(0, 8 * g.size + 7);
        let xs = noisy_vec(g, len);
        let mut ys = noisy_vec(g, len);
        // Plant exact zero divisors so DivOrZero's guard is exercised on
        // both sides of the lane boundary.
        for i in (0..len).step_by(3) {
            if g.bool() {
                ys[i] = 0.0;
            }
        }
        for op in BINARY_KINDS {
            let mut a = xs.clone();
            let mut b = xs.clone();
            (s.binary)(op, &mut a, &ys);
            (d.binary)(op, &mut b, &ys);
            for i in 0..len {
                prop_assert!(
                    a[i].to_bits() == b[i].to_bits(),
                    "{op:?} diverged at {i} (len {len}): {} vs {} (a={}, b={})",
                    a[i],
                    b[i],
                    xs[i],
                    ys[i]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn gemm_acc_bit_identical_scalar_vs_detected() {
    let (s, d) = (kernels::scalar(), kernels::detected());
    check("gemm-bit-identical", |g| {
        // Sizes include empty dims and column counts straddling the 8-lane
        // micro-kernel width (n % 8 != 0 exercises the column tail).
        let m = g.usize_in(0, g.size);
        let k = g.usize_in(0, 2 * g.size);
        let n = g.usize_in(0, 20);
        let a = noisy_vec(g, m * k);
        let b = noisy_vec(g, k * n);
        let c0 = g.f32_vec(m * n, 2.0);
        let mut ca = c0.clone();
        let mut cb = c0;
        (s.gemm_acc)(&mut ca, &a, &b, m, k, n);
        (d.gemm_acc)(&mut cb, &a, &b, m, k, n);
        for i in 0..m * n {
            prop_assert!(
                ca[i].to_bits() == cb[i].to_bits(),
                "gemm {m}x{k}x{n} diverged at {i}: {} vs {}",
                ca[i],
                cb[i]
            );
        }
        Ok(())
    });
}

#[test]
fn dist2_bit_identical_scalar_vs_detected() {
    let (s, d) = (kernels::scalar(), kernels::detected());
    check("dist2-bit-identical", |g| {
        let len = g.usize_in(0, 8 * g.size + 7);
        let a = noisy_vec(g, len);
        let b = noisy_vec(g, len);
        let x = (s.dist2)(&a, &b);
        let y = (d.dist2)(&a, &b);
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "dist2 diverged (len {len}): {x} vs {y}"
        );
        Ok(())
    });
}

/// Sub-task split plans depend only on work size and threshold — never on
/// worker count — so a forced-split run on 4 workers must be bit-identical
/// to a 1-worker run of the same pipeline, and the fat tasks must actually
/// have split (subtasks_spawned > 0 with multiple workers).
#[test]
fn split_runs_bit_identical_across_worker_counts() {
    let _guard = split_lock();
    let prev = kernels::set_split_min(1024);
    let m = DenseMatrix::from_fn(96, 64, |i, j| ((i * 64 + j) % 13) as f32 * 0.37 - 2.0);
    let w = DenseMatrix::from_fn(64, 80, |i, j| ((i + 3 * j) % 11) as f32 * 0.21 - 1.0);
    let run = |workers: usize| {
        let rt = Runtime::local(workers);
        let a = creation::from_matrix(&rt, &m, (96, 64)).unwrap();
        let b = creation::from_matrix(&rt, &w, (64, 80)).unwrap();
        let mm = a.matmul(&b).unwrap().collect().unwrap();
        let ew = a
            .add_scalar(1.0)
            .unwrap()
            .mul_scalar(0.5)
            .unwrap()
            .collect()
            .unwrap();
        let pd = a.pairwise_dist2(&a).unwrap().collect().unwrap();
        (mm, ew, pd, rt.metrics().subtasks_spawned)
    };
    let (mm1, ew1, pd1, _) = run(1);
    let (mm4, ew4, pd4, subs4) = run(4);
    kernels::set_split_min(prev);
    assert_eq!(mm1, mm4, "gemm split changed results");
    assert_eq!(ew1, ew4, "fused elementwise split changed results");
    assert_eq!(pd1, pd4, "pairwise distance split changed results");
    assert!(subs4 > 0, "fat tasks never split (subtasks_spawned = 0)");
}
