//! Robustness: failure injection into the task runtime, determinism of the
//! simulator, stress shapes (degenerate grids, deep chains, wide fan-outs
//! under contention), and lineage-recovery edge cases against in-process
//! cluster workers (peer-pull death, only-holder death, multi-level
//! replay).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use rustdslib::bench::report;
use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::storage::{Block, BlockMeta, DenseMatrix};
use rustdslib::tasking::cluster::serve_worker;
use rustdslib::tasking::wire::{self, Request};
use rustdslib::tasking::{ClusterOptions, CostHint, Runtime, SimConfig, TaskFn, WorkerOptions};
use rustdslib::util::rng::Xoshiro256;

/// Start an in-process cluster worker (real wire protocol, same daemon
/// loop as `dsarray worker`, just a thread instead of an OS process) and
/// return its address.
fn inproc_worker() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = serve_worker(l, WorkerOptions::default());
    });
    addr
}

/// Like [`inproc_worker`], but carrying a deterministic fault spec
/// (`die@N` / `drop@N` / `slow@N`).
fn inproc_worker_with(fault_spec: &str) -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    let opts = WorkerOptions {
        fault_spec: Some(fault_spec.to_string()),
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = serve_worker(l, opts);
    });
    addr
}

/// Crash an in-process worker over the wire: it drops its blocks, stops
/// answering, and refuses new connections — a process death as seen from
/// every peer, without killing the test process. The EOF on the (absent)
/// response confirms the dead flag is up before we return.
fn crash_worker_at(addr: &str) {
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    wire::write_request(&mut s, &Request::Crash).unwrap();
    let _ = wire::read_response(&mut s);
}

fn dense_val(b: &Block) -> &DenseMatrix {
    match b {
        Block::Dense(m) => m,
        other => panic!("expected dense block, got {other:?}"),
    }
}

#[test]
fn mid_graph_failure_poisons_dependents_not_process() {
    let rt = Runtime::local(3);
    let src = rt.put_block(Block::Dense(DenseMatrix::full(1, 1, 1.0)));
    // A healthy branch...
    let ok = rt.submit(
        "ok",
        &[src],
        vec![BlockMeta::dense(1, 1)],
        CostHint::default(),
        Arc::new(|ins: &[Arc<Block>]| Ok(vec![(*ins[0]).clone()])),
    );
    // ...and a failing branch with dependents.
    let boom = rt.submit(
        "boom",
        &[src],
        vec![BlockMeta::dense(1, 1)],
        CostHint::default(),
        Arc::new(|_| anyhow::bail!("injected failure")),
    );
    let dep = rt.submit(
        "dep",
        &[boom[0]],
        vec![BlockMeta::dense(1, 1)],
        CostHint::default(),
        Arc::new(|ins: &[Arc<Block>]| Ok(vec![(*ins[0]).clone()])),
    );
    // The healthy result may or may not be retrievable depending on
    // poisoning order; what MUST hold: dependents of the failure error out,
    // the barrier reports the failure, and nothing hangs or crashes.
    let _ = rt.wait(ok[0]);
    assert!(rt.wait(dep[0]).is_err());
    let err = rt.barrier().unwrap_err().to_string();
    assert!(err.contains("injected failure"), "{err}");
}

#[test]
fn every_worker_keeps_draining_after_failures() {
    // 50 failing + 200 succeeding tasks interleaved: all successes must
    // still have executed (fail-fast poisons waits, not the pool).
    let rt = Runtime::local(4);
    let counter = Arc::new(AtomicUsize::new(0));
    let src = rt.put_block(Block::Dense(DenseMatrix::full(1, 1, 0.0)));
    for i in 0..250 {
        let c = Arc::clone(&counter);
        if i % 5 == 0 {
            rt.submit(
                "fail",
                &[src],
                vec![BlockMeta::dense(1, 1)],
                CostHint::default(),
                Arc::new(|_| anyhow::bail!("nope")),
            );
        } else {
            rt.submit(
                "work",
                &[src],
                vec![BlockMeta::dense(1, 1)],
                CostHint::default(),
                Arc::new(move |_| {
                    c.fetch_add(1, Ordering::Relaxed);
                    Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])
                }),
            );
        }
    }
    let _ = rt.barrier(); // errors (poisoned) as soon as the first failure lands
    // Fail-fast poisons waits immediately, but already-submitted healthy
    // tasks keep draining — wait for quiescence before asserting.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while counter.load(Ordering::Relaxed) < 200 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(counter.load(Ordering::Relaxed), 200);
}

#[test]
fn sim_is_deterministic() {
    let run = || {
        let rt = Runtime::sim(SimConfig::with_workers(16));
        let a = creation::phantom(&rt, (512, 256), (64, 64), None).unwrap();
        let t = a.transpose().unwrap();
        let _ = t.matmul(&a).unwrap();
        let _ = a.shuffle_rows(9).unwrap();
        rt.run_sim().unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.tasks_executed, r2.tasks_executed);
    assert!((r1.makespan_s - r2.makespan_s).abs() < 1e-12);
    assert!((r1.master_busy_s - r2.master_busy_s).abs() < 1e-12);
    assert_eq!(r1.bytes_transferred, r2.bytes_transferred);
}

#[test]
fn sim_worker_monotonicity() {
    // More workers never increases makespan for the same graph (list
    // scheduling on identical masters; master cost grows with cores, so
    // allow the known master-bound exception by testing a compute-heavy
    // graph).
    let mk = |workers| {
        let rt = Runtime::sim(SimConfig::with_workers(workers));
        let src = rt.put_block(Block::Phantom(BlockMeta::dense(1, 1)));
        for _ in 0..256 {
            rt.submit(
                "flops",
                &[src],
                vec![BlockMeta::dense(1, 1)],
                CostHint::flops(4e9), // 2s each
                Arc::new(|_| Ok(vec![Block::Dense(DenseMatrix::zeros(1, 1))])),
            );
        }
        rt.run_sim().unwrap().makespan_s
    };
    let t2 = mk(2);
    let t8 = mk(8);
    let t32 = mk(32);
    assert!(t2 > t8 && t8 > t32, "{t2} {t8} {t32}");
}

#[test]
fn degenerate_grids() {
    let rt = Runtime::local(2);
    // 1x1 array.
    let one = creation::from_matrix(&rt, &DenseMatrix::full(1, 1, 3.0), (1, 1)).unwrap();
    assert_eq!(one.transpose().unwrap().collect().unwrap().get(0, 0), 3.0);
    assert_eq!(one.sum().unwrap(), 3.0);
    // Single row, many columns.
    let row = creation::from_matrix(
        &rt,
        &DenseMatrix::from_fn(1, 30, |_, j| j as f32),
        (1, 7),
    )
    .unwrap();
    let t = row.transpose().unwrap();
    assert_eq!(t.shape(), (30, 1));
    assert_eq!(t.collect().unwrap().get(29, 0), 29.0);
    // Block bigger than the array.
    let big = creation::from_matrix(&rt, &DenseMatrix::full(3, 3, 1.0), (10, 10)).unwrap();
    assert_eq!(big.grid(), (1, 1));
    assert_eq!(big.sum().unwrap(), 9.0);
}

#[test]
fn deep_dependency_chain_under_contention() {
    // A 500-deep chain interleaved with a 500-wide fan-out on 2 workers.
    let rt = Runtime::local(2);
    let a = creation::from_matrix(&rt, &DenseMatrix::full(4, 4, 1.0), (2, 2)).unwrap();
    let mut chain = a.clone();
    for _ in 0..125 {
        chain = chain.add_scalar(1.0).unwrap(); // 4 blocks per step
    }
    let wide: Vec<_> = (0..100)
        .map(|i| a.mul_scalar(i as f32).unwrap())
        .collect();
    let got = chain.collect().unwrap();
    assert_eq!(got.get(0, 0), 126.0);
    for (i, w) in wide.iter().enumerate() {
        assert_eq!(w.collect().unwrap().get(3, 3), i as f32);
    }
}

/// A worker dies while serving a worker-to-worker pull: the task's
/// placement worker reports the dead *peer*, the coordinator marks the
/// peer lost, re-loads its root block from the journal, and the task
/// completes with the right value — no poison, no hang.
#[test]
fn pull_peer_death_recovers_via_root_journal() {
    let w0 = inproc_worker();
    let w1 = inproc_worker();
    let rt = Runtime::cluster(ClusterOptions {
        addrs: vec![w0, w1.clone()],
        ..Default::default()
    })
    .unwrap();
    // Round-robin placement: the fat block lands on worker 0, the small
    // one on worker 1 — so the task runs on 0 (most input bytes) and must
    // pull across to reach the small block.
    let big = rt.put_block(Block::Dense(DenseMatrix::full(32, 32, 2.0)));
    let small = rt.put_block(Block::Dense(DenseMatrix::full(2, 2, 40.0)));
    // The small block's only holder dies before the pull happens.
    crash_worker_at(&w1);
    let sum = rt.submit(
        "sum2",
        &[big, small],
        vec![BlockMeta::dense(2, 2)],
        CostHint::default(),
        Arc::new(|ins: &[Arc<Block>]| {
            let a = dense_val(&ins[0]).get(0, 0);
            let b = dense_val(&ins[1]);
            Ok(vec![Block::Dense(DenseMatrix::from_fn(2, 2, |i, j| a + b.get(i, j)))])
        }),
    );
    let out = rt.wait(sum[0]).unwrap();
    assert_eq!(dense_val(&out).get(1, 1), 42.0);
    let met = rt.metrics();
    assert_eq!(met.workers_lost, 1, "the pull peer's death must be observed");
    assert!(met.blocks_recovered >= 1, "the peer's root block was lost and re-loaded");
}

/// The only holder of a task's output dies while a `wait` fetch is in
/// flight: the fetch error triggers recovery, the producing task is
/// replayed on the survivor (its root input re-loaded from the journal),
/// and the same `wait` call returns the recovered value.
#[test]
fn only_holder_death_during_collect_fetch_replays_producer() {
    let w0 = inproc_worker();
    let w1 = inproc_worker();
    let rt = Runtime::cluster(ClusterOptions {
        addrs: vec![w0.clone(), w1],
        ..Default::default()
    })
    .unwrap();
    let src = rt.put_block(Block::Dense(DenseMatrix::full(2, 2, 20.0)));
    let inc = rt.submit(
        "inc",
        &[src],
        vec![BlockMeta::dense(2, 2)],
        CostHint::default(),
        Arc::new(|ins: &[Arc<Block>]| {
            let m = dense_val(&ins[0]);
            Ok(vec![Block::Dense(DenseMatrix::from_fn(2, 2, |i, j| m.get(i, j) + 1.0))])
        }),
    );
    rt.barrier().unwrap();
    // Locality put both the root and the output on worker 0. Kill it: the
    // fetch below races a dead socket, not a planned failure path.
    crash_worker_at(&w0);
    let out = rt.wait(inc[0]).unwrap();
    assert_eq!(dense_val(&out).get(0, 0), 21.0);
    let met = rt.metrics();
    assert_eq!(met.workers_lost, 1);
    assert!(met.tasks_replayed >= 1, "the producer must have been replayed");
    assert!(met.blocks_recovered >= 1);
}

/// Two-level lineage walk: a chain `root → t1 → t2` lives entirely on one
/// worker; when that worker dies, replaying `t2` requires first replaying
/// `t1` (whose own input is also lost and journal-covered). Both levels
/// replay, in order, on the survivor.
#[test]
fn two_level_lineage_walk_replays_chain() {
    let w0 = inproc_worker();
    let w1 = inproc_worker();
    let rt = Runtime::cluster(ClusterOptions {
        addrs: vec![w0.clone(), w1],
        ..Default::default()
    })
    .unwrap();
    let plus_one = || -> TaskFn {
        Arc::new(|ins: &[Arc<Block>]| {
            let m = dense_val(&ins[0]);
            Ok(vec![Block::Dense(DenseMatrix::from_fn(2, 2, |i, j| m.get(i, j) + 1.0))])
        })
    };
    let a = rt.put_block(Block::Dense(DenseMatrix::full(2, 2, 1.0)));
    let t1 = rt.submit("lvl1", &[a], vec![BlockMeta::dense(2, 2)], CostHint::default(), plus_one());
    let t2 =
        rt.submit("lvl2", &[t1[0]], vec![BlockMeta::dense(2, 2)], CostHint::default(), plus_one());
    rt.barrier().unwrap();
    // The whole chain sits on worker 0 (root placement + locality).
    crash_worker_at(&w0);
    let out = rt.wait(t2[0]).unwrap();
    assert_eq!(dense_val(&out).get(1, 0), 3.0);
    let met = rt.metrics();
    assert_eq!(met.workers_lost, 1);
    assert!(met.tasks_replayed >= 2, "both chain levels must replay, got {}", met.tasks_replayed);
    assert!(met.blocks_recovered >= 3, "root + both intermediates were lost");
}

/// Elasticity churn chaos: mid-KMeans, the fleet loses a worker to a
/// SIGKILL-style crash, gains a freshly joined one, gracefully drains a
/// survivor, and has a fourth member turn into a straggler that only the
/// heartbeat can notice — and the fit stays **bit-identical** to the
/// fault-free local run for every pinned seed. Failing seeds reproduce
/// with `DSARRAY_CHAOS_SEEDS=<seed>` (the same env var the process-level
/// chaos suite pins in CI).
#[test]
fn membership_churn_mid_kmeans_stays_bit_identical() {
    let seeds: Vec<u64> = match std::env::var("DSARRAY_CHAOS_SEEDS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("bad DSARRAY_CHAOS_SEEDS entry"))
            .collect(),
        Err(_) => vec![606, 707, 808],
    };
    for seed in seeds {
        let round = std::panic::catch_unwind(|| churn_round(seed));
        if round.is_err() {
            panic!("churn seed {seed} diverged; rerun with DSARRAY_CHAOS_SEEDS={seed}");
        }
    }
}

fn churn_round(seed: u64) {
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xe1a5);
    let m = DenseMatrix::from_fn(48, 8, |_, _| rng.next_normal());
    let fit = |rt: &Runtime, churn: &mut dyn FnMut(&Runtime)| {
        let x = creation::from_matrix(rt, &m, (8, 8)).unwrap();
        // The shift guarantees produced (not just journal-covered root)
        // blocks are at stake when members disappear.
        let y = x.add_scalar(1.0).unwrap();
        rt.barrier().unwrap();
        churn(rt);
        let mut km = KMeans::new(KMeansConfig {
            k: 3,
            max_iter: 6,
            tol: 1e-9,
            seed,
        });
        km.fit_dsarray(&y).unwrap();
        (km.centers.clone().unwrap(), km.inertia)
    };
    let (centers_local, inertia_local) = fit(&Runtime::local(2), &mut |_| {});

    // Three boot members; the third is a scheduled straggler whose stall
    // state the heartbeat (whose own pings count as served requests) is
    // guaranteed to both trigger and then detect.
    let victim = (seed % 2) as usize;
    let drained = 1 - victim;
    let addrs = vec![
        inproc_worker(),
        inproc_worker(),
        inproc_worker_with("slow@10"),
    ];
    let rt = Runtime::cluster(
        ClusterOptions {
            addrs: addrs.clone(),
            heartbeat_ms: 40,
            straggler_factor: 4.0,
            ..Default::default()
        },
    )
    .unwrap();
    let (centers_cluster, inertia_cluster) = fit(&rt, &mut |rt| {
        // One member dies hard (unobserved until something touches it)...
        crash_worker_at(&addrs[victim]);
        // ...a fresh worker enrolls mid-run...
        let joined = inproc_worker();
        rt.cluster_join(&joined).unwrap();
        // ...and a healthy survivor is gracefully decommissioned. Its
        // sole-copy migration may pick the dead victim as a target first,
        // exercising the drain's retry-on-target-death path.
        rt.cluster_drain(drained).unwrap();
    });
    assert_eq!(
        centers_cluster, centers_local,
        "churn seed {seed}: centroids diverged from the fault-free local run"
    );
    assert_eq!(inertia_cluster, inertia_local);
    // The straggler's heartbeat death may land after the fit completes (its
    // probes keep advancing the slow worker's request counter until the
    // stall state trips), so give the monitor a moment to converge on
    // `workers_lost == 2`: the crash victim plus the stalled member.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while rt.metrics().workers_lost < 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let met = rt.metrics();
    assert_eq!(met.workers_joined, 1);
    assert_eq!(met.workers_drained, 1);
    assert_eq!(
        met.workers_lost, 2,
        "the crash and the heartbeat-detected straggler must both count"
    );
    assert!(met.tasks_by_worker.len() >= 3, "{:?}", met.tasks_by_worker);
    // The elasticity counters flow through the metrics line verbatim.
    let json = report::metrics_json(&met);
    assert!(json.contains("\"workers_joined\":1"), "{json}");
    assert!(json.contains("\"workers_drained\":1"), "{json}");
    assert!(json.contains("\"tasks_speculated\":"), "{json}");
}

#[test]
fn pairwise_artifact_round_trip() {
    let Some(svc) = rustdslib::runtime::global() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = rustdslib::util::rng::Xoshiro256::seed_from_u64(3);
    let x = DenseMatrix::from_fn(40, 10, |_, _| rng.next_normal());
    let y = DenseMatrix::from_fn(25, 10, |_, _| rng.next_normal());
    let d2 = rustdslib::runtime::exec::pairwise_dist2(svc, &x, &y).unwrap();
    assert_eq!((d2.rows(), d2.cols()), (40, 25));
    for i in [0usize, 17, 39] {
        for j in [0usize, 11, 24] {
            let want: f32 = (0..10)
                .map(|c| {
                    let t = x.get(i, c) - y.get(j, c);
                    t * t
                })
                .sum();
            assert!((d2.get(i, j) - want).abs() < 1e-2, "({i},{j})");
        }
    }
}
