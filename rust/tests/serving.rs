//! Serving-tier acceptance suite: a fitted model hosted by `ModelServer`
//! over **real worker processes** must answer thousands of concurrent
//! single-row predict requests bit-identically to the local batch
//! `predict`, with the micro-batcher visibly coalescing, admission control
//! shedding explicitly (never hanging, never OOMing), and — with k-way
//! replication — a SIGKILLed worker costing **zero** failed requests.
//!
//! Also covers the model-artifact round trip (every estimator, including a
//! fit over a spill-budget runtime) and worker-initiated graceful
//! shutdown: a real `dsarray worker --join` process receiving SIGTERM asks
//! the coordinator to drain it and exits cleanly mid-traffic.

use std::path::Path;
use std::process::Child;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rustdslib::dsarray::creation;
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::{Estimator, LinearRegression, Pca, StandardScaler};
use rustdslib::serving::{ModelArtifact, ModelServer, PredictOutcome, ServeOptions, ServingClient};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::cluster::spawn_worker_process_with;
use rustdslib::tasking::{ClusterOptions, Runtime};
use rustdslib::util::rng::Xoshiro256;

/// A fleet of real worker processes; killed (and reaped) on drop. Same
/// harness as `tests/cluster.rs`.
struct Workers {
    children: Vec<Child>,
    addrs: Vec<String>,
}

impl Workers {
    fn spawn(n: usize) -> Self {
        let program = Path::new(env!("CARGO_BIN_EXE_dsarray"));
        let mut children = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..n {
            let (child, addr) =
                spawn_worker_process_with(program, None, None).expect("spawn dsarray worker");
            children.push(child);
            addrs.push(addr);
        }
        Self { children, addrs }
    }

    fn runtime(&self) -> Runtime {
        Runtime::cluster(ClusterOptions {
            addrs: self.addrs.clone(),
            ..Default::default()
        })
        .unwrap()
    }

    fn runtime_with(&self, opts: ClusterOptions) -> Runtime {
        Runtime::cluster(opts).unwrap()
    }
}

impl Drop for Workers {
    fn drop(&mut self) {
        for c in &mut self.children {
            match c.try_wait() {
                Ok(Some(_)) => {}
                _ => {
                    c.kill().ok();
                    c.wait().ok();
                }
            }
        }
    }
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> DenseMatrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    DenseMatrix::from_fn(rows, cols, |_, _| rng.next_normal())
}

/// Fit a KMeans on `xm` locally and return (artifact, per-row reference
/// labels from the **batch** `predict` path). Blocks span the full feature
/// width — the layout under which the serving task is bit-identical to the
/// batch closure (see `docs/SERVING.md`).
fn fitted_kmeans_reference(xm: &DenseMatrix) -> (ModelArtifact, DenseMatrix) {
    let rt = Runtime::local(2);
    let x = creation::from_matrix(&rt, xm, (64.min(xm.rows()), xm.cols())).unwrap();
    let mut km = KMeans::new(KMeansConfig {
        k: 4,
        max_iter: 10,
        tol: 1e-9,
        seed: 9,
    });
    km.fit(&x, None).unwrap();
    let reference = km.predict(&x).unwrap().collect().unwrap();
    let artifact = ModelArtifact::from_kmeans(&km).unwrap();
    // The serving predict path must agree with the batch path up front —
    // any divergence here would invalidate the whole saturation assert.
    assert_eq!(artifact.predict_rows(xm).unwrap(), reference);
    (artifact, reference)
}

/// The tentpole acceptance scenario: ≥1000 concurrent single-row requests
/// from many client threads against a server backed by two real worker
/// processes. Every request is answered, every answer is bit-identical to
/// the local batch `predict`, and the micro-batcher demonstrably coalesced
/// (`batches_coalesced > 0`) — batching changes latency, never values.
#[test]
fn saturation_thousands_of_requests_stay_bit_identical() {
    const THREADS: usize = 16;
    const PER_THREAD: usize = 80; // 1280 requests total

    let xm = random_matrix(256, 8, 31);
    let (artifact, reference) = fitted_kmeans_reference(&xm);

    let workers = Workers::spawn(2);
    let server = ModelServer::new(
        workers.runtime(),
        ServeOptions::default().with_batch_window_ms(5).with_max_batch_rows(256),
    );
    server.register("km", artifact).unwrap();
    let handle = server.serve(std::net::TcpListener::bind("127.0.0.1:0").unwrap()).unwrap();
    let addr = handle.addr().to_string();

    let answered = Arc::new(AtomicU64::new(0));
    let threads: Vec<_> = (0..THREADS)
        .map(|t| {
            let addr = addr.clone();
            let xm = xm.clone();
            let reference = reference.clone();
            let answered = answered.clone();
            std::thread::spawn(move || {
                let mut c = ServingClient::connect(&addr).unwrap();
                for k in 0..PER_THREAD {
                    let i = (t * PER_THREAD + k) % xm.rows();
                    let row = xm.slice(i, 0, 1, xm.cols()).unwrap();
                    match c.predict("km", &row).unwrap() {
                        PredictOutcome::Predicted(got) => {
                            assert_eq!(
                                got,
                                reference.slice(i, 0, 1, 1).unwrap(),
                                "served row {i} diverged from the batch predict"
                            );
                            answered.fetch_add(1, Ordering::SeqCst);
                        }
                        PredictOutcome::Shed(reason) => {
                            panic!("no request should shed at these caps: {reason}")
                        }
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let total = (THREADS * PER_THREAD) as u64;
    assert_eq!(answered.load(Ordering::SeqCst), total);
    let s = handle.stats();
    assert_eq!(s.requests_served, total, "every request must be served");
    assert_eq!(s.requests_shed, 0);
    assert!(
        s.batches_coalesced > 0,
        "concurrent traffic must coalesce, got {} batches",
        s.batches_coalesced
    );
    assert_eq!(
        s.latency_us_hist.iter().sum::<u64>(),
        total,
        "every served request must land in a latency bucket"
    );
    // The serving counters flow through the metrics line verbatim.
    let json = rustdslib::bench::report::metrics_json(&handle.metrics());
    assert!(json.contains(&format!("\"requests_served\":{total}")), "{json}");
    assert!(json.contains("\"batches_coalesced\":"), "{json}");
    assert!(json.contains("\"predict_latency_us_hist\":["), "{json}");
    handle.shutdown();
}

/// Overload is shed at the door with an explicit `Overloaded` frame — and
/// the server recovers: once the burst drains, fresh requests are served
/// again. Every request gets exactly one explicit outcome; none hang.
#[test]
fn admission_control_sheds_explicitly_and_recovers() {
    let xm = random_matrix(64, 8, 37);
    let (artifact, reference) = fitted_kmeans_reference(&xm);

    // Local backend: this test is about the queue, not the wire to workers.
    let server = ModelServer::new(
        Runtime::local(2),
        ServeOptions::default()
            .with_batch_window_ms(40)
            .with_max_batch_rows(4)
            .with_max_pending_rows(4),
    );
    server.register("km", artifact).unwrap();
    let handle = server.serve(std::net::TcpListener::bind("127.0.0.1:0").unwrap()).unwrap();
    let addr = handle.addr().to_string();

    let served = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let threads: Vec<_> = (0..24)
        .map(|t| {
            let addr = addr.clone();
            let xm = xm.clone();
            let reference = reference.clone();
            let (served, shed) = (served.clone(), shed.clone());
            std::thread::spawn(move || {
                let mut c = ServingClient::connect(&addr).unwrap();
                let i = t % xm.rows();
                let row = xm.slice(i, 0, 1, xm.cols()).unwrap();
                match c.predict("km", &row).unwrap() {
                    PredictOutcome::Predicted(got) => {
                        assert_eq!(got, reference.slice(i, 0, 1, 1).unwrap());
                        served.fetch_add(1, Ordering::SeqCst);
                    }
                    PredictOutcome::Shed(reason) => {
                        assert!(reason.contains("budget"), "shed reason: {reason}");
                        shed.fetch_add(1, Ordering::SeqCst);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let (n_served, n_shed) = (served.load(Ordering::SeqCst), shed.load(Ordering::SeqCst));
    assert_eq!(n_served + n_shed, 24, "every request answered exactly once");
    assert!(n_shed > 0, "24 bursty requests over a 4-row cap must shed some");
    assert!(n_served > 0, "admission control must not shed everything");
    let s = handle.stats();
    assert_eq!(s.requests_served, n_served);
    assert_eq!(s.requests_shed, n_shed);

    // Recovery: the burst is gone, a fresh request sails through.
    let mut c = ServingClient::connect(&addr).unwrap();
    let row = xm.slice(0, 0, 1, xm.cols()).unwrap();
    assert!(matches!(c.predict("km", &row).unwrap(), PredictOutcome::Predicted(_)));
    handle.shutdown();
}

/// Serving under churn, pinned by the chaos-seed convention
/// (`DSARRAY_CHAOS_SEEDS=<seed>` reruns a failing round): with 2-way
/// replication, SIGKILLing one of two workers mid-traffic costs **zero**
/// failed requests — every answer still bit-identical. The seed varies the
/// traffic shape and kill timing.
#[test]
fn worker_sigkill_with_replication_costs_zero_failed_requests() {
    let seeds: Vec<u64> = match std::env::var("DSARRAY_CHAOS_SEEDS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("bad DSARRAY_CHAOS_SEEDS entry"))
            .take(2)
            .collect(),
        Err(_) => vec![101, 202],
    };
    for seed in seeds {
        let round = std::panic::catch_unwind(|| churn_round(seed));
        if round.is_err() {
            panic!("serving churn seed {seed} failed; rerun with DSARRAY_CHAOS_SEEDS={seed}");
        }
    }
}

fn churn_round(seed: u64) {
    let n_threads = 6 + (seed % 4) as usize;
    let per_thread = 40;
    let kill_after_ms = 20 + (seed % 7) * 10;

    let xm = random_matrix(128, 8, seed ^ 0x5bd1);
    let (artifact, reference) = fitted_kmeans_reference(&xm);

    let mut workers = Workers::spawn(2);
    let rt = workers.runtime_with(
        ClusterOptions {
            addrs: workers.addrs.clone(),
            replicate: 2,
            ..Default::default()
        },
    );
    let server = ModelServer::new(rt.clone(), ServeOptions::default().with_batch_window_ms(3));
    server.register("km", artifact).unwrap();
    let handle = server.serve(std::net::TcpListener::bind("127.0.0.1:0").unwrap()).unwrap();
    let addr = handle.addr().to_string();

    let threads: Vec<_> = (0..n_threads)
        .map(|t| {
            let addr = addr.clone();
            let xm = xm.clone();
            let reference = reference.clone();
            std::thread::spawn(move || {
                let mut c = ServingClient::connect(&addr).unwrap();
                for k in 0..per_thread {
                    let i = (t * per_thread + k) % xm.rows();
                    let row = xm.slice(i, 0, 1, xm.cols()).unwrap();
                    // Zero failed requests: `.unwrap()` on the call (no
                    // transport/task error) and no shed at these caps.
                    match c.predict("km", &row).unwrap() {
                        PredictOutcome::Predicted(got) => {
                            assert_eq!(got, reference.slice(i, 0, 1, 1).unwrap())
                        }
                        PredictOutcome::Shed(reason) => panic!("unexpected shed: {reason}"),
                    }
                }
            })
        })
        .collect();

    // Mid-traffic SIGKILL: half the replicas die. Replication (plus the
    // lineage walk for anything in flight) absorbs it.
    std::thread::sleep(Duration::from_millis(kill_after_ms));
    workers.children[0].kill().unwrap();
    workers.children[0].wait().unwrap();

    for t in threads {
        t.join().unwrap();
    }
    let s = handle.stats();
    assert_eq!(s.requests_served, (n_threads * per_thread) as u64);
    assert_eq!(s.requests_shed, 0);
    let met = handle.metrics();
    assert!(met.workers_lost >= 1, "the kill must be observed, got {}", met.workers_lost);
    handle.shutdown();
}

/// Companion contract without the safety net: replication off **and**
/// recovery off, worker SIGKILLed mid-traffic. Requests may fail — but
/// each gets an explicit error (`Err` on the call, or a served answer that
/// is still bit-identical); nothing hangs and the server stays up.
#[test]
fn worker_sigkill_without_replication_degrades_cleanly() {
    let xm = random_matrix(128, 8, 53);
    let (artifact, reference) = fitted_kmeans_reference(&xm);

    let mut workers = Workers::spawn(2);
    let rt = workers.runtime_with(
        ClusterOptions {
            addrs: workers.addrs.clone(),
            recovery: false,
            ..Default::default()
        },
    );
    let server = ModelServer::new(rt, ServeOptions::default().with_batch_window_ms(3));
    server.register("km", artifact).unwrap();
    let handle = server.serve(std::net::TcpListener::bind("127.0.0.1:0").unwrap()).unwrap();
    let addr = handle.addr().to_string();

    let ok = Arc::new(AtomicU64::new(0));
    let explicit_err = Arc::new(AtomicU64::new(0));
    let threads: Vec<_> = (0..6)
        .map(|t| {
            let addr = addr.clone();
            let xm = xm.clone();
            let reference = reference.clone();
            let (ok, explicit_err) = (ok.clone(), explicit_err.clone());
            std::thread::spawn(move || {
                let mut c = ServingClient::connect(&addr).unwrap();
                for k in 0..40 {
                    let i = (t * 40 + k) % xm.rows();
                    let row = xm.slice(i, 0, 1, xm.cols()).unwrap();
                    match c.predict("km", &row) {
                        Ok(PredictOutcome::Predicted(got)) => {
                            assert_eq!(got, reference.slice(i, 0, 1, 1).unwrap());
                            ok.fetch_add(1, Ordering::SeqCst);
                        }
                        Ok(PredictOutcome::Shed(_)) | Err(_) => {
                            // Explicit degradation — the contract here.
                            explicit_err.fetch_add(1, Ordering::SeqCst);
                            // The transport may be gone; reconnect and go on.
                            if let Ok(fresh) = ServingClient::connect(&addr) {
                                c = fresh;
                            }
                        }
                    }
                }
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(30));
    workers.children[0].kill().unwrap();
    workers.children[0].wait().unwrap();

    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(
        ok.load(Ordering::SeqCst) + explicit_err.load(Ordering::SeqCst),
        6 * 40,
        "every request must resolve explicitly — no hangs"
    );
    assert!(ok.load(Ordering::SeqCst) > 0, "requests before the kill must have succeeded");
    handle.shutdown();
}

fn temp_artifact(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("dsarray-serving-{}-{tag}.dsma", std::process::id()))
}

/// Round-trip property for every estimator kind: save → load reproduces
/// the artifact exactly (`PartialEq`) and the reloaded `predict_rows` is
/// bit-identical to the fitted estimator's distributed batch `predict`.
#[test]
fn artifact_round_trip_bit_identical_for_every_estimator() {
    let rt = Runtime::local(2);
    let xm = random_matrix(64, 6, 71);
    let x = creation::from_matrix(&rt, &xm, (16, 6)).unwrap();

    let mut km = KMeans::new(KMeansConfig { k: 3, max_iter: 8, tol: 1e-9, seed: 4 });
    km.fit(&x, None).unwrap();
    let mut lr = LinearRegression::default();
    let ym = DenseMatrix::from_fn(64, 1, |i, _| xm.get(i, 0) * 2.0 - xm.get(i, 3) + 0.25);
    let y = creation::from_matrix(&rt, &ym, (16, 1)).unwrap();
    lr.fit(&x, Some(&y)).unwrap();
    let mut sc = StandardScaler::default();
    sc.fit(&x).unwrap();
    let mut pca = Pca::new(2);
    pca.fit(&x, None).unwrap();

    let cases: Vec<(&str, ModelArtifact, DenseMatrix)> = vec![
        ("kmeans", ModelArtifact::from_kmeans(&km).unwrap(), km.predict(&x).unwrap().collect().unwrap()),
        ("linreg", ModelArtifact::from_linreg(&lr).unwrap(), lr.predict(&x).unwrap().collect().unwrap()),
        ("scaler", ModelArtifact::from_scaler(&sc).unwrap(), sc.transform(&x).unwrap().collect().unwrap()),
        ("pca", ModelArtifact::from_pca(&pca).unwrap(), pca.predict(&x).unwrap().collect().unwrap()),
    ];
    for (tag, artifact, batch_reference) in cases {
        let path = temp_artifact(tag);
        let bytes = artifact.save_path(&path).unwrap();
        assert!(bytes > 0);
        let loaded = ModelArtifact::load_path(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded, artifact, "{tag}: decode(encode(a)) != a");
        assert_eq!(
            loaded.predict_rows(&xm).unwrap(),
            batch_reference,
            "{tag}: reloaded predict diverged from the batch predict"
        );
    }
}

/// The round trip holds when the fit ran over a spill-budget runtime:
/// spilling through the block store must not perturb the fitted parameters
/// or the reloaded predictions by a single bit.
#[test]
fn artifact_round_trip_survives_spill_budget_fit() {
    let xm = random_matrix(64, 6, 83);
    let fit = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &xm, (16, 6)).unwrap();
        let mut km = KMeans::new(KMeansConfig { k: 3, max_iter: 8, tol: 1e-9, seed: 4 });
        km.fit(&x, None).unwrap();
        (ModelArtifact::from_kmeans(&km).unwrap(), km.predict(&x).unwrap().collect().unwrap())
    };
    let (plain, reference) = fit(&Runtime::local(2));
    // Each 16x6 f32 block is 384 B; a 1 KiB budget forces spills mid-fit.
    let budget_rt = Runtime::local_with_budget(2, 1024).unwrap();
    let (budgeted, budget_reference) = fit(&budget_rt);
    assert_eq!(budgeted, plain, "spilling must not change fitted parameters");
    assert_eq!(budget_reference, reference);

    let path = temp_artifact("spill");
    budgeted.save_path(&path).unwrap();
    let loaded = ModelArtifact::load_path(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, budgeted);
    assert_eq!(loaded.predict_rows(&xm).unwrap(), reference);
}

/// Worker-initiated graceful shutdown (ROADMAP item 1 remainder): a real
/// `dsarray worker --join` process receives SIGTERM mid-traffic, asks the
/// coordinator to drain it (DRAINING/DRAINED on stdout), exits **zero**,
/// and the in-flight fit completes bit-identically on the survivor.
#[test]
#[cfg(unix)]
fn sigterm_drains_joined_worker_mid_fit() {
    use std::io::BufRead;

    let m = random_matrix(64, 8, 91);
    let fit = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &m, (8, 8)).unwrap();
        let mut km = KMeans::new(KMeansConfig { k: 4, max_iter: 12, tol: 1e-9, seed: 6 });
        km.fit(&x, None).unwrap();
        (km.centers.unwrap(), km.inertia)
    };
    let (centers_local, inertia_local) = fit(&Runtime::local(2));

    let mut workers = Workers::spawn(1);
    let rt = workers.runtime();
    let control = rt.cluster_control_addr().expect("cluster runtimes expose a control address");

    let program = Path::new(env!("CARGO_BIN_EXE_dsarray"));
    let mut child = std::process::Command::new(program)
        .args(["worker", "--listen", "127.0.0.1:0", "--join", &control])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn joining dsarray worker");
    let mut lines = std::io::BufReader::new(child.stdout.take().unwrap()).lines();
    let listening = lines.next().expect("LISTENING line").unwrap();
    assert!(listening.starts_with("LISTENING "), "{listening}");
    let joined = lines.next().expect("JOINED line").unwrap();
    assert_eq!(joined, format!("JOINED {control}"));

    // Put real blocks on both members so the drain has bytes to migrate.
    let x = creation::from_matrix(&rt, &m, (8, 8)).unwrap();
    rt.barrier().unwrap();
    drop(x);

    // Fit in the background while the joined worker is told to leave.
    let fit_thread = {
        let rt = rt.clone();
        std::thread::spawn(move || fit(&rt))
    };
    std::thread::sleep(Duration::from_millis(20));
    let status = std::process::Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("send SIGTERM");
    assert!(status.success());

    // The worker must drain and exit cleanly (code 0, not the signal).
    let mut exit = None;
    for _ in 0..300 {
        if let Some(st) = child.try_wait().unwrap() {
            exit = Some(st);
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let (centers_cluster, inertia_cluster) = fit_thread.join().unwrap();
    let exit = match exit {
        Some(st) => st,
        None => {
            child.kill().ok();
            child.wait().ok();
            panic!("SIGTERMed worker did not exit within 30s");
        }
    };
    assert!(exit.success(), "drained worker must exit 0, got {exit:?}");
    let out: Vec<String> = lines.map(|l| l.unwrap()).collect();
    assert!(out.iter().any(|l| l.starts_with("DRAINING ")), "stdout: {out:?}");
    assert!(out.iter().any(|l| l.starts_with("DRAINED ")), "stdout: {out:?}");

    assert_eq!(centers_cluster, centers_local, "fit across the drain must be bit-identical");
    assert_eq!(inertia_cluster, inertia_local);
    let met = rt.metrics();
    assert!(met.workers_drained >= 1, "drain must be counted, got {}", met.workers_drained);
    // Keep the static worker alive until here; Drop reaps it.
    drop(workers);
}
