//! Out-of-core end-to-end tests: workloads whose working set exceeds the
//! runtime memory budget must produce results identical to unconstrained
//! in-memory runs, with the spill/fault counters proving the budget was
//! actually exercised. (PR acceptance: a KMeans fit over a `load_csv`-
//! ingested array at half-footprint budget matches the unconstrained run.)

use rustdslib::dsarray::{creation, io as dsio};
use rustdslib::estimators::kmeans::{KMeans, KMeansConfig};
use rustdslib::estimators::{Estimator, Pca};
use rustdslib::storage::DenseMatrix;
use rustdslib::tasking::Runtime;
use rustdslib::util::rng::Xoshiro256;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rustdslib_ooc_{name}_{}", std::process::id()))
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> DenseMatrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    DenseMatrix::from_fn(rows, cols, |_, _| rng.next_normal())
}

/// The PR's acceptance scenario: CSV-ingested KMeans at half-footprint
/// budget equals the unconstrained run, spills and faults both fire, and
/// load-time residency stays bounded by budget + one block-row (the master
/// never materializes the matrix).
#[test]
fn kmeans_on_spill_backed_load_csv_matches_unconstrained() {
    let m = random_matrix(64, 8, 11);
    let p = tmp("kmeans.csv");
    rustdslib::storage::io::write_csv(&p, &m, ',').unwrap();

    let footprint = (64 * 8 * 4) as u64; // 2048 B
    let block_row_bytes = (8 * 8 * 4) as u64; // (8, 8) blocks, one per block-row
    let fit = |rt: &Runtime| {
        let x = dsio::load_csv(rt, &p, (8, 8), ',').unwrap();
        let load_peak = rt.metrics().peak_resident_bytes;
        let mut km = KMeans::new(KMeansConfig {
            k: 4,
            max_iter: 8,
            tol: 1e-9,
            seed: 5,
        });
        km.fit(&x, None).unwrap();
        (km.centers.unwrap(), km.inertia, load_peak)
    };

    let rt_mem = Runtime::local(2);
    let (centers_mem, inertia_mem, _) = fit(&rt_mem);

    let rt_ooc = Runtime::local_with_budget(2, footprint / 2).unwrap();
    let (centers_ooc, inertia_ooc, load_peak) = fit(&rt_ooc);

    // Same task graph, same arithmetic: bit-identical centroids.
    assert_eq!(centers_ooc, centers_mem);
    assert_eq!(inertia_ooc, inertia_mem);
    let met = rt_ooc.metrics();
    assert!(met.blocks_spilled > 0, "budget must force spills");
    assert!(met.blocks_faulted > 0, "fit must fault spilled blocks back");
    assert!(met.spill_bytes > 0);
    // Ingestion streams block-rows through the budget: residency during
    // load is bounded by budget + one block-row, far below the footprint.
    assert!(
        load_peak <= footprint / 2 + block_row_bytes,
        "load peak {load_peak} exceeds budget {} + one block-row {block_row_bytes}",
        footprint / 2
    );
    assert!(load_peak < footprint);

    // The hard streaming proof: with a budget of ONE block-row, the whole
    // 8-block-row load flows through a single-block-row window — the
    // master-side path never materializes the matrix.
    let rt_tiny = Runtime::local_with_budget(2, block_row_bytes).unwrap();
    let x = dsio::load_csv(&rt_tiny, &p, (8, 8), ',').unwrap();
    x.runtime().barrier().unwrap();
    assert!(
        rt_tiny.metrics().peak_resident_bytes <= 2 * block_row_bytes,
        "peak {} with a one-block-row budget",
        rt_tiny.metrics().peak_resident_bytes
    );
    assert_eq!(x.collect().unwrap(), m);
    std::fs::remove_file(&p).ok();
}

#[test]
fn matmul_with_working_set_over_budget_matches_in_memory() {
    let ma = random_matrix(64, 64, 21);
    let mb = random_matrix(64, 64, 22);
    let run = |rt: &Runtime| {
        let a = creation::from_matrix(rt, &ma, (16, 16)).unwrap();
        let b = creation::from_matrix(rt, &mb, (16, 16)).unwrap();
        a.matmul(&b).unwrap().collect().unwrap()
    };
    let expect = run(&Runtime::local(2));
    // Working set is 3 x 16 KiB (a, b, c); budget fits half of one array.
    let rt = Runtime::local_with_budget(2, 8 * 1024).unwrap();
    let got = run(&rt);
    assert_eq!(got, expect, "spill-backed matmul must be bit-identical");
    let met = rt.metrics();
    assert!(met.blocks_spilled > 0 && met.blocks_faulted > 0);
    assert!(met.resident_bytes <= 8 * 1024 + 1024, "budget enforced up to one block");
}

/// Deferred elementwise expressions and lazy views over a (partly) spilled
/// parent must force correctly — the fused tasks and gather tasks fault
/// their inputs like any other reader.
#[test]
fn deferred_expr_and_view_over_spilled_parent_force_correctly() {
    let m = random_matrix(64, 64, 33);
    // Budget of 4 blocks out of 64: registration itself spills.
    let rt = Runtime::local_with_budget(2, 4 * 8 * 8 * 4).unwrap();
    let a = creation::from_matrix(&rt, &m, (8, 8)).unwrap();
    assert!(rt.metrics().blocks_spilled > 0, "registration over budget spills");

    // Fused expression chain over the spilled parent (parent stays alive:
    // shared reads, every input faulted on demand).
    let got = a
        .add_scalar(1.0)
        .unwrap()
        .mul_scalar(0.5)
        .unwrap()
        .collect()
        .unwrap();
    let mut want = m.map(|x| (x + 1.0) * 0.5);
    assert_eq!(got, want);

    // Unaligned lazy view over the spilled parent: force() gathers across
    // block boundaries, faulting the touched backing blocks.
    let v = a.slice(3, 61, 5, 50).unwrap();
    assert!(v.is_view());
    let forced = v.force().unwrap();
    assert_eq!(forced.collect().unwrap(), m.slice(3, 5, 58, 45).unwrap());

    // In-place execution over a dead spilled intermediate: the exclusive
    // grant faults the buffer in first, then mutates it in place.
    let tmp = a.add_scalar(0.0).unwrap().force().unwrap();
    rt.barrier().unwrap();
    let chain = tmp.mul_scalar(3.0).unwrap();
    drop(tmp);
    let before = rt.metrics();
    let got = chain.collect().unwrap();
    want = m.map(|x| x * 3.0);
    assert_eq!(got, want);
    let delta = rt.metrics().since(&before);
    assert!(delta.inplace_hits > 0, "dead intermediate should be granted in place");
}

/// Estimators run unmodified on spill-backed arrays: PCA at a quarter of
/// the footprint equals the in-memory fit exactly.
#[test]
fn pca_on_spill_backed_array_matches_in_memory() {
    let m = random_matrix(96, 16, 44);
    let run = |rt: &Runtime| {
        let x = creation::from_matrix(rt, &m, (12, 16)).unwrap();
        let mut pca = Pca::new(4);
        pca.fit(&x, None).unwrap();
        pca.components.unwrap()
    };
    let expect = run(&Runtime::local(2));
    let rt = Runtime::local_with_budget(2, (96 * 16 * 4) / 4).unwrap();
    let got = run(&rt);
    assert_eq!(got, expect);
    assert!(rt.metrics().blocks_spilled > 0);
}

/// Plan-layer parity under a spill budget: KMeans, ALS, and PCA fits at
/// `Level::Off` and `Level::Full` — both runtimes built through the
/// `Runtime::builder()` front door with the same memory budget — produce
/// bit-identical models, the optimizer strictly shrinks `tasks_submitted`
/// in the metrics line, and the budget still actually spills (the
/// composed reduce tails and pre-released gemm operands change *when*
/// blocks die, never what the spill tier reads back).
#[test]
fn optimizer_parity_kmeans_als_pca_off_vs_full_under_budget() {
    use rustdslib::bench::report;
    use rustdslib::estimators::als::AlsConfig;
    use rustdslib::estimators::Als;
    use rustdslib::plan::Level;

    let xm = random_matrix(64, 8, 71);
    let rm = random_matrix(24, 16, 72);
    let budget = (64 * 8 * 4) / 2; // half the KMeans footprint
    let run = |level: Level| {
        let rt = Runtime::builder()
            .workers(2)
            .memory_budget_bytes(budget as u64)
            .optimizer(level)
            .build()
            .unwrap();
        let x = creation::from_matrix(&rt, &xm, (8, 8)).unwrap();
        let mut km = KMeans::new(KMeansConfig {
            k: 3,
            max_iter: 6,
            tol: 1e-9,
            seed: 5,
        });
        km.fit(&x, None).unwrap();
        let mut pca = Pca::new(2);
        pca.fit(&x, None).unwrap();
        let r = creation::from_matrix(&rt, &rm, (6, 4)).unwrap();
        let mut als = Als::new(AlsConfig {
            d: 3,
            lambda: 0.05,
            max_iter: 3,
            seed: 9,
        });
        als.fit_dsarray(&r).unwrap();
        let met = rt.metrics();
        assert!(met.blocks_spilled > 0, "budget must spill at level {level:?}");
        (
            km.centers.unwrap(),
            km.inertia,
            pca.components.unwrap(),
            als.u.unwrap(),
            als.v.unwrap(),
            report::metrics_json(&met),
        )
    };
    let (c_off, i_off, p_off, u_off, v_off, j_off) = run(Level::Off);
    let (c_full, i_full, p_full, u_full, v_full, j_full) = run(Level::Full);
    assert_eq!(c_full, c_off, "KMeans centroid parity across optimizer levels");
    assert_eq!(i_full, i_off, "KMeans inertia parity");
    assert_eq!(p_full, p_off, "PCA component parity");
    assert_eq!(u_full, u_off, "ALS U parity");
    assert_eq!(v_full, v_off, "ALS V parity");

    let submitted = |j: &str| {
        rustdslib::util::json::parse(j)
            .expect("metrics line parses")
            .get("tasks_submitted")
            .and_then(|v| v.as_f64())
            .expect("tasks_submitted present") as u64
    };
    let (s_off, s_full) = (submitted(&j_off), submitted(&j_full));
    assert!(
        s_full < s_off,
        "optimizer must strictly shrink tasks_submitted: {s_full} vs {s_off}"
    );
}

/// Parallel partitioned save/load under budget: write-back never needs the
/// master to hold the array, and the round trip is exact.
#[test]
fn partitioned_save_load_round_trip_under_budget() {
    let m = random_matrix(48, 12, 55);
    let rt = Runtime::local_with_budget(2, 4 * 8 * 12 * 4).unwrap();
    let a = creation::from_matrix(&rt, &m, (8, 12)).unwrap();
    let dir = tmp("parts");
    dsio::save_csv_parts(&a, &dir, ',').unwrap();
    let back = dsio::load_csv_parts(&rt, &dir, 4, ',').unwrap();
    assert_eq!(back.collect().unwrap(), m);

    let npy = tmp("rt.npy");
    dsio::save_npy(&a, &npy).unwrap();
    let back = dsio::load_npy(&rt, &npy, (8, 4)).unwrap();
    assert_eq!(back.collect().unwrap(), m);
    assert!(rt.metrics().blocks_spilled > 0);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&npy).ok();
}
