//! End-to-end PJRT integration: load the real AOT artifacts (built by
//! `make artifacts`), compile them on the PJRT CPU client, execute from
//! multiple threads, and check numerics against the native Rust oracles.
//!
//! These tests are skipped (not failed) when artifacts/ has not been built,
//! so `cargo test` stays useful before the Python step; `make test` always
//! builds artifacts first.

use rustdslib::runtime::{exec, global};
use rustdslib::storage::DenseMatrix;
use rustdslib::util::rng::Xoshiro256;

fn svc() -> Option<&'static rustdslib::runtime::PjrtService> {
    let s = global();
    if s.is_none() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    s
}

fn randm(rng: &mut Xoshiro256, r: usize, c: usize) -> DenseMatrix {
    DenseMatrix::from_fn(r, c, |_, _| rng.next_normal())
}

#[test]
fn gemm_artifact_matches_native() {
    let Some(svc) = svc() else { return };
    let mut rng = Xoshiro256::seed_from_u64(1);
    for (m, k, n) in [(64, 64, 64), (10, 20, 30), (128, 128, 128), (65, 64, 3)] {
        let a = randm(&mut rng, m, k);
        let b = randm(&mut rng, k, n);
        let c = randm(&mut rng, m, n);
        let got = exec::gemm_acc(svc, &a, &b, &c).unwrap();
        let mut want = c.clone();
        want.axpy(1.0, &a.matmul(&b).unwrap()).unwrap();
        assert!(
            got.max_abs_diff(&want) < 1e-3,
            "({m},{k},{n}): diff {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn gemm_tn_artifact_matches_native() {
    let Some(svc) = svc() else { return };
    let mut rng = Xoshiro256::seed_from_u64(2);
    let a = randm(&mut rng, 48, 32); // (k, m)
    let b = randm(&mut rng, 48, 16); // (k, n)
    let c = DenseMatrix::zeros(32, 16);
    let got = exec::gemm_tn_acc(svc, &a, &b, &c).unwrap();
    let want = a.transpose().matmul(&b).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-3);
}

#[test]
fn kmeans_artifact_matches_native_assignment() {
    let Some(svc) = svc() else { return };
    let mut rng = Xoshiro256::seed_from_u64(3);
    let (m, f, k) = (50, 12, 3);
    let x = randm(&mut rng, m, f);
    let centers = randm(&mut rng, k, f);
    let (psum, pcount, pssd) = exec::kmeans_assign(svc, &x, &centers).unwrap();

    // Native oracle.
    let mut want_sum = DenseMatrix::zeros(k, f);
    let mut want_count = vec![0.0f32; k];
    let mut want_ssd = 0.0f64;
    for i in 0..m {
        let mut best = (f32::INFINITY, 0usize);
        for kk in 0..k {
            let d2: f32 = (0..f)
                .map(|j| {
                    let d = x.get(i, j) - centers.get(kk, j);
                    d * d
                })
                .sum();
            if d2 < best.0 {
                best = (d2, kk);
            }
        }
        want_ssd += best.0 as f64;
        want_count[best.1] += 1.0;
        for j in 0..f {
            let v = want_sum.get(best.1, j) + x.get(i, j);
            want_sum.set(best.1, j, v);
        }
    }
    assert!(psum.max_abs_diff(&want_sum) < 1e-2, "psum diff");
    for kk in 0..k {
        assert_eq!(pcount.get(0, kk), want_count[kk], "count {kk}");
    }
    assert!((pssd as f64 - want_ssd).abs() / want_ssd.max(1.0) < 1e-3);
}

#[test]
fn standardize_and_col_stats_match_native() {
    let Some(svc) = svc() else { return };
    let mut rng = Xoshiro256::seed_from_u64(4);
    let x = randm(&mut rng, 40, 10);
    let (sums, sumsq) = exec::col_stats(svc, &x).unwrap();
    let want_s = x.sum_axis(0);
    assert!(sums.max_abs_diff(&want_s) < 1e-3);
    let want_q = x.map(|v| v * v).sum_axis(0);
    assert!(sumsq.max_abs_diff(&want_q) < 1e-3);

    let mean = sums.map(|s| s / 40.0);
    let inv = DenseMatrix::from_fn(1, 10, |_, j| {
        let mu = mean.get(0, j);
        let var = sumsq.get(0, j) / 40.0 - mu * mu;
        1.0 / (var + 1e-8).sqrt()
    });
    let got = exec::standardize(svc, &x, &mean, &inv).unwrap();
    // Standardized columns have ~0 mean, ~1 std.
    let col_mean = got.sum_axis(0).map(|s| s / 40.0);
    for j in 0..10 {
        assert!(col_mean.get(0, j).abs() < 1e-3, "col {j} mean");
    }
}

#[test]
fn service_is_callable_from_many_threads() {
    let Some(svc) = svc() else { return };
    let handles: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let svc = global().unwrap();
                let mut rng = Xoshiro256::seed_from_u64(100 + t);
                for _ in 0..5 {
                    let a = randm(&mut rng, 32, 32);
                    let b = randm(&mut rng, 32, 32);
                    let c = DenseMatrix::zeros(32, 32);
                    let got = exec::gemm_acc(svc, &a, &b, &c).unwrap();
                    let want = a.matmul(&b).unwrap();
                    assert!(got.max_abs_diff(&want) < 1e-3);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let _ = svc;
}

#[test]
fn bad_input_shapes_rejected() {
    let Some(svc) = svc() else { return };
    // Direct call with non-canonical shape must error, not crash.
    let r = svc.call("gemm_64", vec![DenseMatrix::zeros(3, 3)]);
    assert!(r.is_err());
    let r = svc.call(
        "gemm_64",
        vec![
            DenseMatrix::zeros(3, 3),
            DenseMatrix::zeros(64, 64),
            DenseMatrix::zeros(64, 64),
        ],
    );
    assert!(r.is_err());
    let r = svc.call("no_such_artifact", vec![]);
    assert!(r.is_err());
}
