//! CI benchmark regression gate.
//!
//! Compares a freshly produced `BENCH_hotpath.json` (written by
//! `cargo bench --bench hotpath -- --json …`) against the committed
//! baseline at the repository root and **fails (exit 1) when the median
//! regression of any watched row group exceeds the threshold** (default
//! 25%, groups `matmul`, `fused`, `load`, `kernel`, `split`, `recovery`,
//! `elastic`, `serving`, `planner` — the rows the perf PRs optimize;
//! `kernel` tracks the scalar-vs-SIMD micro-kernel rows, `split` the
//! whole-block-vs-sub-task rows, `recovery` the kill-mid-gemm
//! fault-recovery wall time, `elastic` the drain-migration and
//! straggler-speculation wall times, `serving` the p50 single-row
//! predict latency through the micro-batcher, and `planner` the
//! optimizer-on vs optimizer-off task-stream timings).
//!
//! Median-per-group, not worst-row, so one noisy timing on a shared CI
//! runner cannot fail the gate by itself; the threshold absorbs the rest of
//! the runner-to-runner variance. Individual rows present on only one side
//! are reported but never gate (new benchmarks must not fail their own PR)
//! — **except** when a watched group has baseline rows and the current run
//! produced *none of them*: a whole group silently disappearing means the
//! benchmark was dropped or renamed, and the gate FAILS rather than letting
//! the coverage evaporate. A baseline with no timed rows at all (the
//! committed seed, or a bench format change) cannot gate anything: the run
//! SKIPS with a loud warning instead of silently "passing" — the
//! push-to-main refresh step repopulates it.
//!
//! Usage:
//!   bench_gate --baseline ../BENCH_hotpath.json --current BENCH_hotpath.json \
//!              [--max-regress 0.25] \
//!              [--groups matmul,fused,load,kernel,split,recovery,elastic,serving,planner]

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};
use rustdslib::util::cli::Args;
use rustdslib::util::json::Json;

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("bench_gate: {e:#}");
            std::process::exit(2);
        }
    }
}

fn run() -> Result<bool> {
    let args = Args::from_env();
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow!("--baseline <path> is required"))?;
    let current_path = args
        .get("current")
        .ok_or_else(|| anyhow!("--current <path> is required"))?;
    let max_regress = args.get_f64("max-regress", 0.25);
    let groups: Vec<String> = args
        .get_str(
            "groups",
            "matmul,fused,load,kernel,split,recovery,elastic,serving,planner",
        )
        .split(',')
        .map(|g| g.trim().to_string())
        .filter(|g| !g.is_empty())
        .collect();

    let baseline = load_rows(baseline_path)?;
    let current = load_rows(current_path)?;

    if baseline.is_empty() {
        println!(
            "bench_gate: WARNING: baseline {baseline_path} has no baseline rows — gate skipped. \
             Nothing was compared; this run verifies only that the current artifact parses. \
             The next push to main commits a real baseline and re-arms the gate."
        );
        return Ok(true);
    }

    println!(
        "bench_gate: {} baseline rows vs {} current rows; gate = median regression \
         > {:.0}% on any of {:?}",
        baseline.len(),
        current.len(),
        max_regress * 100.0,
        groups
    );
    let mut ok = true;
    for group in &groups {
        let mut regressions: Vec<f64> = Vec::new();
        let mut current_in_group = 0usize;
        let mut baseline_in_group = 0usize;
        println!("-- group `{group}`");
        for (name, cur) in &current {
            if !name.contains(group.as_str()) {
                continue;
            }
            current_in_group += 1;
            match baseline.get(name) {
                Some(base) => {
                    let reg = (cur - base) / base;
                    regressions.push(reg);
                    println!(
                        "   {name}: {base:.6}s -> {cur:.6}s ({:+.1}%)",
                        reg * 100.0
                    );
                }
                None => println!("   {name}: {cur:.6}s (new row, not gated)"),
            }
        }
        // Baseline rows that vanished from the current run: an individual
        // renamed row only warns (its siblings still gate the group), but a
        // group whose every baseline row is missing FAILS below — a dropped
        // benchmark must not silently retire its own coverage.
        for (name, base) in &baseline {
            if name.contains(group.as_str()) {
                baseline_in_group += 1;
                if !current.contains_key(name) {
                    println!("   {name}: {base:.6}s -> MISSING from current run");
                }
            }
        }
        if baseline_in_group > 0 && current_in_group == 0 {
            ok = false;
            println!(
                "   FAIL: baseline has {baseline_in_group} `{group}` row(s) but the \
                 current run produced none — benchmark dropped or renamed"
            );
            continue;
        }
        match median(&mut regressions) {
            None => println!("   no comparable rows — group passes vacuously"),
            Some(med) if med > max_regress => {
                ok = false;
                println!(
                    "   FAIL: median regression {:+.1}% exceeds {:.0}%",
                    med * 100.0,
                    max_regress * 100.0
                );
            }
            Some(med) => println!("   ok: median regression {:+.1}%", med * 100.0),
        }
    }
    if ok {
        println!("bench_gate: PASS");
    } else {
        println!("bench_gate: FAIL — see regressing groups above");
    }
    Ok(ok)
}

/// `name -> secs` for every finite, positive timing row of one artifact.
fn load_rows(path: &str) -> Result<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v: Json = rustdslib::util::json::parse(&text)
        .map_err(|e| anyhow!("{e}"))
        .with_context(|| format!("parsing {path}"))?;
    let mut out = BTreeMap::new();
    if let Some(rows) = v.get("rows").and_then(|r| r.as_arr()) {
        for row in rows {
            let name = row.get("name").and_then(|n| n.as_str());
            let secs = row.get("secs").and_then(|s| s.as_f64());
            let (Some(name), Some(secs)) = (name, secs) else {
                continue; // informational rows carry null secs
            };
            if secs.is_finite() && secs > 0.0 {
                out.insert(name.to_string(), secs);
            }
        }
    }
    Ok(out)
}

fn median(xs: &mut [f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite regressions"));
    let n = xs.len();
    Some(if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    })
}
