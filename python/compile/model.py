"""L2: the block-compute graphs the Rust coordinator executes via PJRT.

Each entry point is a jax function composing the L1 Pallas kernels; aot.py
lowers every (entry point, canonical shape) pair to an HLO-text artifact
that `rust/src/runtime/` loads and runs on the request path. Shapes are
static under AOT, so the Rust side pads edge blocks to the canonical block
size and masks where padding would corrupt results.

Entry points (canonical block edge S ∈ {64, 128}, f32):
  gemm_<S>:           C + A @ B                         (S,S)³ → (S,S)
  gemm_tn_<S>:        C + Aᵀ @ B                        (S,S)³ → (S,S)
  kmeans_<S>_k8:      fused assignment step             (S,S),(8,S),(S,1)
  standardize_<S>:    (X - μ) σ⁻¹                       (S,S),(1,S),(1,S)
  col_stats_<S>:      masked column sums / sumsq        (S,S),(S,1)
  scaler_fit_<S>:     composed: stats → (μ, σ⁻¹)        (S,S),(S,1),(1,1)
"""

import jax.numpy as jnp

from compile.kernels import elementwise, gemm, kmeans, pairwise

#: Number of K-means centers baked into the AOT kmeans artifacts. Rust pads
#: unused center rows to +inf so no sample ever selects them.
KMEANS_K = 8


def gemm_acc(a, b, c):
    """C + A @ B (delegates to the tiled Pallas kernel)."""
    return (gemm.gemm_acc(a, b, c),)


def gemm_tn_acc(a, b, c):
    """C + Aᵀ @ B — ALS/Gram accumulate."""
    return (gemm.gemm_tn_acc(a, b, c),)


def kmeans_step(x, centers, mask):
    """Fused K-means assignment over one block: (psum, pcount, pssd)."""
    return kmeans.kmeans_assign(x, centers, mask)


def standardize(x, mean, inv_std):
    """Scaler transform for one block."""
    return (elementwise.standardize(x, mean, inv_std),)


def col_stats(x, mask):
    """Masked column statistics for one block: (sums, sumsq)."""
    return elementwise.col_stats(x, mask)


def scaler_fit(x, mask, n_valid):
    """Composed L2 graph: block stats → (mean, inv_std) for this block alone.

    Demonstrates a multi-kernel L2 graph (stats kernel + jnp epilogue) and is
    used by the single-block fast path of the StandardScaler. `n_valid` is a
    (1, 1) float carrying the valid-row count.
    """
    sums, sumsq = elementwise.col_stats(x, mask)
    n = jnp.maximum(n_valid, 1.0)
    mean = sums / n
    var = jnp.maximum(sumsq / n - mean * mean, 0.0)
    inv_std = 1.0 / jnp.sqrt(var + 1e-8)
    return mean, inv_std


def pairwise_dist2(x, y):
    """Pairwise squared distances for one query block vs a reference set."""
    return (pairwise.pairwise_dist2(x, y),)


def _shape(*dims):
    import jax

    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def entry_points(sizes=(64, 128)):
    """(name, fn, example_args) for every artifact aot.py must produce."""
    eps = []
    for s in sizes:
        eps.append((f"gemm_{s}", gemm_acc, (_shape(s, s), _shape(s, s), _shape(s, s))))
        eps.append(
            (f"gemm_tn_{s}", gemm_tn_acc, (_shape(s, s), _shape(s, s), _shape(s, s)))
        )
        eps.append(
            (
                f"kmeans_{s}_k{KMEANS_K}",
                kmeans_step,
                (_shape(s, s), _shape(KMEANS_K, s), _shape(s, 1)),
            )
        )
        eps.append(
            (
                f"standardize_{s}",
                standardize,
                (_shape(s, s), _shape(1, s), _shape(1, s)),
            )
        )
        eps.append((f"col_stats_{s}", col_stats, (_shape(s, s), _shape(s, 1))))
        eps.append(
            (
                f"scaler_fit_{s}",
                scaler_fit,
                (_shape(s, s), _shape(s, 1), _shape(1, 1)),
            )
        )
        eps.append(
            (
                f"pairwise_{s}",
                pairwise_dist2,
                (_shape(s, s), _shape(s, s)),
            )
        )
    return eps
