"""L1 Pallas kernel: fused K-means assignment step.

The per-block K-means work — pairwise squared distances, argmin assignment,
per-center partial sums/counts, and the inertia contribution — is fused into
ONE kernel so a sample block is read from HBM exactly once (the unfused
pipeline reads it three times: distances, one-hot matmul, reduction).

The grid tiles the sample axis; centers stay resident in VMEM across steps
(their BlockSpec index map is constant) while each step streams one
(bm, f) sample tile. Outputs are accumulated across grid steps in VMEM.
Padding rows are masked so edge blocks of a ds-array can be padded to the
canonical AOT shape without corrupting sums or counts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(x_ref, c_ref, m_ref, psum_ref, pcount_ref, pssd_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        psum_ref[...] = jnp.zeros_like(psum_ref)
        pcount_ref[...] = jnp.zeros_like(pcount_ref)
        pssd_ref[...] = jnp.zeros_like(pssd_ref)

    x = x_ref[...]  # (bm, f)
    c = c_ref[...]  # (k, f)
    mask = m_ref[...]  # (bm, 1)

    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    c2 = jnp.sum(c * c, axis=1)  # (k,)
    d2 = x2 - 2.0 * jnp.dot(x, c.T, preferred_element_type=x.dtype) + c2[None, :]
    d2 = jnp.maximum(d2, 0.0)  # clamp fp cancellation
    assign = jnp.argmin(d2, axis=1)  # (bm,)
    k = c.shape[0]
    onehot = (assign[:, None] == jax.lax.iota(jnp.int32, k)[None, :]).astype(
        x.dtype
    ) * mask  # (bm, k)

    psum_ref[...] += jnp.dot(onehot.T, x, preferred_element_type=x.dtype)
    pcount_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)
    pssd_ref[...] += jnp.sum(jnp.min(d2, axis=1, keepdims=True) * mask).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("bm",))
def kmeans_assign(x, centers, mask, *, bm=64):
    """Fused assignment step; see `ref.kmeans_assign` for the math.

    Args:
      x: (m, f) sample block (rows may be padding).
      centers: (k, f) centers.
      mask: (m, 1) row validity (1.0 valid / 0.0 padding).
      bm: sample-axis tile size.

    Returns:
      (psum (k, f), pcount (1, k), pssd (1, 1)).
    """
    m, f = x.shape
    k = centers.shape[0]
    assert centers.shape == (k, f) and mask.shape == (m, 1)
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _kmeans_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),  # resident
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, f), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, f), x.dtype),
            jax.ShapeDtypeStruct((1, k), x.dtype),
            jax.ShapeDtypeStruct((1, 1), x.dtype),
        ],
        interpret=True,
    )(x, centers, mask)
