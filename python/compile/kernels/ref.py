"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

Each function here is the mathematical specification the corresponding
kernel in this package must match under ``assert_allclose``; pytest +
hypothesis sweep shapes/dtypes against these (python/tests/test_kernel.py).
"""

import jax.numpy as jnp


def gemm_acc(a, b, c):
    """C + A @ B (matmul-accumulate)."""
    return c + jnp.matmul(a, b, preferred_element_type=c.dtype)


def gemm_tn_acc(a, b, c):
    """C + A^T @ B — the Gram-style accumulate used by ALS."""
    return c + jnp.matmul(a.T, b, preferred_element_type=c.dtype)


def kmeans_assign(x, centers, mask):
    """One K-means assignment step over a block of samples.

    Args:
      x: (m, f) samples (padding rows allowed).
      centers: (k, f) current centers.
      mask: (m, 1) 1.0 for valid rows, 0.0 for padding.

    Returns:
      psum: (k, f) per-center partial sums of assigned valid samples.
      pcount: (1, k) per-center assigned-sample counts.
      pssd: (1, 1) summed squared distance of valid samples (inertia part).
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (m, 1)
    c2 = jnp.sum(centers * centers, axis=1)  # (k,)
    d2 = x2 - 2.0 * x @ centers.T + c2[None, :]  # (m, k)
    d2 = jnp.maximum(d2, 0.0)
    assign = jnp.argmin(d2, axis=1)  # (m,)
    onehot = (assign[:, None] == jnp.arange(centers.shape[0])[None, :]).astype(
        x.dtype
    ) * mask  # (m, k)
    psum = onehot.T @ x  # (k, f)
    pcount = jnp.sum(onehot, axis=0, keepdims=True)  # (1, k)
    pssd = jnp.sum(jnp.min(d2, axis=1, keepdims=True) * mask).reshape(1, 1)
    return psum, pcount, pssd


def standardize(x, mean, inv_std):
    """(x - mean) * inv_std with row broadcast; mean/inv_std are (1, f)."""
    return (x - mean) * inv_std


def col_stats(x, mask):
    """Masked per-column sums and sums of squares.

    Returns (1, f) sums and (1, f) sums of squares over valid rows.
    """
    xm = x * mask
    return jnp.sum(xm, axis=0, keepdims=True), jnp.sum(xm * x, axis=0, keepdims=True)


def pairwise_dist2(x, y):
    """Squared Euclidean distances between rows of x (m,f) and y (k,f)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1)
    return jnp.maximum(x2 - 2.0 * x @ y.T + y2[None, :], 0.0)
