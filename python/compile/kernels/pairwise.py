"""L1 Pallas kernel: pairwise squared Euclidean distances.

Feeds the k-NN estimator: one (bm, f) query tile vs a VMEM-resident
reference set (k, f) per grid step, emitting the (bm, k) distance tile —
the expansion ||x||² − 2x·yᵀ + ||y||² computed in one pass so queries
stream through HBM exactly once.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]  # (bm, f)
    y = y_ref[...]  # (k, f)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    y2 = jnp.sum(y * y, axis=1)  # (k,)
    d2 = x2 - 2.0 * jnp.dot(x, y.T, preferred_element_type=x.dtype) + y2[None, :]
    o_ref[...] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("bm",))
def pairwise_dist2(x, y, *, bm=64):
    """Squared distances between rows of x (m, f) and rows of y (k, f)."""
    m, f = x.shape
    k, f2 = y.shape
    assert f == f2, (x.shape, y.shape)
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=True,
    )(x, y)
