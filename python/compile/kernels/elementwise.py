"""L1 Pallas kernels: fused elementwise chains and masked column statistics.

`standardize` fuses the scaler transform `(x - mean) * inv_std` (one HBM
round-trip instead of two); `col_stats` fuses masked per-column sum and
sum-of-squares (feeding the scaler's fit step), accumulating across
sample-axis tiles in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _standardize_kernel(x_ref, mu_ref, is_ref, o_ref):
    o_ref[...] = (x_ref[...] - mu_ref[...]) * is_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def standardize(x, mean, inv_std, *, bm=64):
    """(x - mean) * inv_std, row-broadcast; mean/inv_std are (1, f)."""
    m, f = x.shape
    assert mean.shape == (1, f) and inv_std.shape == (1, f)
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _standardize_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),  # resident broadcast row
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, f), x.dtype),
        interpret=True,
    )(x, mean, inv_std)


def _col_stats_kernel(x_ref, m_ref, sum_ref, sq_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    x = x_ref[...]
    xm = x * m_ref[...]
    sum_ref[...] += jnp.sum(xm, axis=0, keepdims=True)
    sq_ref[...] += jnp.sum(xm * x, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm",))
def col_stats(x, mask, *, bm=64):
    """Masked per-column (sums, sums of squares); mask is (m, 1)."""
    m, f = x.shape
    assert mask.shape == (m, 1)
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _col_stats_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, f), lambda i: (0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, f), x.dtype),
            jax.ShapeDtypeStruct((1, f), x.dtype),
        ],
        interpret=True,
    )(x, mask)
