"""L1 Pallas kernels: tiled matmul-accumulate (NN and TN variants).

These are the FLOP hot spots of the block operations (`dsarray.matmul`,
`dsarray.gram`, the ALS normal-equation accumulation). The tiling is
TPU-idiomatic (DESIGN.md §Hardware-Adaptation):

* the grid is (M/bm, N/bn, K/bk); each step keeps one (bm, bk) A-tile, one
  (bk, bn) B-tile and the (bm, bn) accumulator in VMEM — the `BlockSpec`s
  express the HBM↔VMEM schedule a CUDA version would write with
  threadblocks;
* the inner `jnp.dot` maps onto the MXU; `preferred_element_type=f32`
  requests full-precision accumulation;
* `interpret=True` at call time because the CPU PJRT plugin cannot execute
  Mosaic custom-calls (the AOT artifacts embed the interpreted lowering).

VMEM budget per step at the default (bm, bn, bk) = (64, 64, 64), f32:
3 tiles × 16 KiB = 48 KiB of live data (≪ 16 MiB VMEM), leaving room for
double-buffering; the 128³ variant uses 192 KiB and fills the 128×128 MXU
exactly (see DESIGN.md §Perf for the utilization estimates).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] @ b[k,j], seeded with c."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _gemm_tn_kernel(a_ref, b_ref, c_ref, o_ref):
    """TN variant: o[i,j] (+)= a[k,i]^T @ b[k,j] (Gram accumulate)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_acc(a, b, c, *, bm=64, bn=64, bk=64):
    """C + A @ B with (bm, bn, bk) VMEM tiles. Shapes must divide evenly."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n), (a.shape, b.shape, c.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(a, b, c)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_tn_acc(a, b, c, *, bm=64, bn=64, bk=64):
    """C + A^T @ B with A (k, m), B (k, n), C (m, n)."""
    k, m = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n), (a.shape, b.shape, c.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _gemm_tn_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(a, b, c)
