"""AOT bridge: lower every L2 entry point to HLO *text* + a manifest.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Outputs one `<name>.hlo.txt` per entry point plus `manifest.json` recording
input/output shapes so the Rust loader can validate its buffers.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(fn, example_args) -> str:
    """Lower a jitted function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def describe(fn, example_args):
    """Input/output shape+dtype signature for the manifest."""
    out = jax.eval_shape(fn, *example_args)
    flat, _ = jax.tree.flatten(out)
    return {
        "inputs": [[list(a.shape), str(a.dtype)] for a in example_args],
        "outputs": [[list(o.shape), str(o.dtype)] for o in flat],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default="64,128",
        help="comma-separated canonical block edges to compile",
    )
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, example_args in model.entry_points(sizes):
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = describe(fn, example_args)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} "
          f"({len(manifest)} entry points)")


if __name__ == "__main__":
    main()
