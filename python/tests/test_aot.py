"""AOT artifact checks: HLO text generation and manifest consistency."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_to_hlo_text_produces_hlo_module():
    name, fn, args = model.entry_points((64,))[0]
    text = aot.to_hlo_text(fn, args)
    assert "HloModule" in text
    assert "f32[64,64]" in text
    # Interpret-mode pallas must lower to plain HLO — no Mosaic custom calls.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_describe_signature():
    name, fn, args = next(
        (n, f, a) for n, f, a in model.entry_points((64,)) if n.startswith("kmeans")
    )
    sig = aot.describe(fn, args)
    assert sig["inputs"][0] == [[64, 64], "float32"]
    assert len(sig["outputs"]) == 3  # psum, pcount, pssd


@pytest.mark.slow
def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--sizes", "64"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest) == 7  # entry points per size (model.entry_points)
    for name in manifest:
        text = (out / f"{name}.hlo.txt").read_text()
        assert "HloModule" in text, name


def test_prebuilt_artifacts_match_manifest():
    """If `make artifacts` has run, the directory must be self-consistent."""
    root = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    art = os.path.join(root, "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    manifest = json.load(open(mpath))
    for name, sig in manifest.items():
        path = os.path.join(art, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        assert sig["inputs"] and sig["outputs"], name
