"""L1 correctness: Pallas kernels vs the pure-jnp oracles in kernels/ref.py.

Hypothesis sweeps shapes, tile sizes and value distributions; every kernel
must match its oracle under assert_allclose. This is the CORE correctness
signal for the compute layer (the Rust side loads exactly these kernels'
AOT lowerings).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, gemm, kmeans, ref

jax.config.update("jax_platform_name", "cpu")

# Tile-friendly dimension strategy: multiples of small tiles up to 128.
def dims(max_tiles=4, tile=16):
    return st.integers(1, max_tiles).map(lambda t: t * tile)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@settings(max_examples=25, deadline=None)
@given(
    m=dims(), n=dims(), k=dims(),
    bm=st.sampled_from([16, 32, 64]),
    bn=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_acc_matches_ref(m, n, k, bm, bn, bk, seed):
    if m % min(bm, m) or n % min(bn, n) or k % min(bk, k):
        pytest.skip("tile does not divide shape")
    rng = np.random.default_rng(seed)
    a, b, c = rand(rng, m, k), rand(rng, k, n), rand(rng, m, n)
    got = gemm.gemm_acc(a, b, c, bm=bm, bn=bn, bk=bk)
    want = ref.gemm_acc(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=dims(), n=dims(), k=dims(),
    bk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_tn_acc_matches_ref(m, n, k, bk, seed):
    if k % min(bk, k):
        pytest.skip("tile does not divide shape")
    rng = np.random.default_rng(seed)
    a, b, c = rand(rng, k, m), rand(rng, k, n), rand(rng, m, n)
    got = gemm.gemm_tn_acc(a, b, c, bk=bk)
    want = ref.gemm_tn_acc(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=dims(), f=dims(),
    kc=st.sampled_from([2, 3, 8]),
    bm=st.sampled_from([16, 32, 64]),
    pad=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmeans_assign_matches_ref(m, f, kc, bm, pad, seed):
    if m % min(bm, m):
        pytest.skip("tile does not divide shape")
    rng = np.random.default_rng(seed)
    x = rand(rng, m, f, scale=2.0)
    centers = rand(rng, kc, f, scale=2.0)
    # Mask out the last `pad` rows as padding.
    pad = min(pad, m - 1)
    mask = jnp.asarray(
        (np.arange(m) < m - pad).astype(np.float32).reshape(m, 1)
    )
    got = kmeans.kmeans_assign(x, centers, mask, bm=bm)
    want = ref.kmeans_assign(x, centers, mask)
    for g, w, name in zip(got, want, ["psum", "pcount", "pssd"]):
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-3, err_msg=name)
    # Counts are integral and sum to the number of valid rows.
    np.testing.assert_allclose(np.sum(got[1]), m - pad, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(m=dims(), f=dims(), bm=st.sampled_from([16, 64]), seed=st.integers(0, 2**31 - 1))
def test_standardize_matches_ref(m, f, bm, seed):
    if m % min(bm, m):
        pytest.skip("tile does not divide shape")
    rng = np.random.default_rng(seed)
    x = rand(rng, m, f, scale=5.0)
    mu = rand(rng, 1, f)
    inv = jnp.abs(rand(rng, 1, f)) + 0.1
    got = elementwise.standardize(x, mu, inv, bm=bm)
    np.testing.assert_allclose(got, ref.standardize(x, mu, inv), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=dims(), f=dims(),
    bm=st.sampled_from([16, 64]),
    pad=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_col_stats_matches_ref(m, f, bm, pad, seed):
    if m % min(bm, m):
        pytest.skip("tile does not divide shape")
    rng = np.random.default_rng(seed)
    x = rand(rng, m, f, scale=3.0)
    pad = min(pad, m - 1)
    mask = jnp.asarray((np.arange(m) < m - pad).astype(np.float32).reshape(m, 1))
    gs, gq = elementwise.col_stats(x, mask, bm=bm)
    ws, wq = ref.col_stats(x, mask)
    np.testing.assert_allclose(gs, ws, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gq, wq, rtol=1e-4, atol=1e-4)


def test_kmeans_padding_centers_never_selected():
    """Rust pads unused center rows with +inf-ish values; verify they get
    zero counts so K < KMEANS_K works through the fixed-shape artifact."""
    rng = np.random.default_rng(0)
    x = rand(rng, 64, 16)
    real = rand(rng, 3, 16)
    padded = jnp.concatenate([real, jnp.full((5, 16), 1e30, jnp.float32)])
    mask = jnp.ones((64, 1), jnp.float32)
    _, pcount, _ = kmeans.kmeans_assign(x, padded, mask)
    assert float(jnp.sum(pcount[0, 3:])) == 0.0
    assert float(jnp.sum(pcount)) == 64.0


def test_gemm_zero_c_is_plain_matmul():
    rng = np.random.default_rng(1)
    a, b = rand(rng, 32, 48), rand(rng, 48, 16)
    got = gemm.gemm_acc(a, b, jnp.zeros((32, 16), jnp.float32), bm=16, bn=16, bk=16)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=dims(), f=dims(),
    kc=st.sampled_from([16, 48, 64]),
    bm=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_dist2_matches_ref(m, f, kc, bm, seed):
    from compile.kernels import pairwise

    if m % min(bm, m):
        pytest.skip("tile does not divide shape")
    rng = np.random.default_rng(seed)
    x = rand(rng, m, f, scale=2.0)
    y = rand(rng, kc, f, scale=2.0)
    got = pairwise.pairwise_dist2(x, y, bm=bm)
    want = ref.pairwise_dist2(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    assert float(jnp.min(got)) >= 0.0


def test_pairwise_self_distance_zero_diagonal():
    from compile.kernels import pairwise

    rng = np.random.default_rng(2)
    x = rand(rng, 32, 16)
    d2 = pairwise.pairwise_dist2(x, x, bm=16)
    np.testing.assert_allclose(jnp.diagonal(d2), 0.0, atol=1e-3)
