"""L2 entry-point checks: shapes, composition, and numeric sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_entry_points_cover_both_sizes():
    eps = model.entry_points((64, 128))
    names = [n for n, _, _ in eps]
    for s in (64, 128):
        for prefix in ("gemm", "gemm_tn", "kmeans", "standardize", "col_stats", "scaler_fit"):
            assert any(n.startswith(f"{prefix}_{s}") for n in names), (prefix, s)
    assert len(names) == len(set(names)), "duplicate entry point names"


def test_entry_point_shapes_evaluate():
    for name, fn, args in model.entry_points((64,)):
        out = jax.eval_shape(fn, *args)
        flat, _ = jax.tree.flatten(out)
        assert flat, name
        for o in flat:
            assert o.dtype == jnp.float32, name


def test_scaler_fit_recovers_moments():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32) * 2.0 + 1.5)
    mask = jnp.ones((64, 1), jnp.float32)
    n = jnp.full((1, 1), 64.0, jnp.float32)
    mean, inv_std = model.scaler_fit(x, mask, n)
    np.testing.assert_allclose(mean, np.mean(np.asarray(x), axis=0, keepdims=True),
                               rtol=1e-3, atol=1e-3)
    want_inv = 1.0 / np.sqrt(np.var(np.asarray(x), axis=0, keepdims=True) + 1e-8)
    np.testing.assert_allclose(inv_std, want_inv, rtol=1e-2, atol=1e-3)


def test_scaler_fit_respects_mask():
    rng = np.random.default_rng(4)
    x_np = rng.standard_normal((64, 16), dtype=np.float32)
    x_np[50:] = 1e6  # padding garbage that the mask must exclude
    x = jnp.asarray(x_np)
    mask = jnp.asarray((np.arange(64) < 50).astype(np.float32).reshape(64, 1))
    n = jnp.full((1, 1), 50.0, jnp.float32)
    mean, _ = model.scaler_fit(x, mask, n)
    np.testing.assert_allclose(
        mean, np.mean(x_np[:50], axis=0, keepdims=True), rtol=1e-3, atol=1e-3
    )


def test_kmeans_step_composes_with_center_update():
    """A full mini K-means loop through the L2 entry point converges on
    two well-separated blobs."""
    rng = np.random.default_rng(5)
    a = rng.standard_normal((32, 8), dtype=np.float32) * 0.1 + 5.0
    b = rng.standard_normal((32, 8), dtype=np.float32) * 0.1 - 5.0
    x = jnp.asarray(np.vstack([a, b]))
    mask = jnp.ones((64, 1), jnp.float32)
    k = model.KMEANS_K
    centers = jnp.asarray(rng.standard_normal((k, 8), dtype=np.float32))
    last = np.inf
    for _ in range(8):
        psum, pcount, pssd = model.kmeans_step(x, centers, mask)
        counts = jnp.maximum(pcount.T, 1e-9)  # (k, 1)
        centers = jnp.where(pcount.T > 0, psum / counts, centers)
        assert float(pssd[0, 0]) <= last + 1e-3
        last = float(pssd[0, 0])
    # Converged inertia is tiny relative to the blob separation.
    assert last < 64 * 8 * 0.1


def test_l2_matches_ref_oracles():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
    c = jnp.asarray(rng.standard_normal((model.KMEANS_K, 64), dtype=np.float32))
    mask = jnp.ones((64, 1), jnp.float32)
    got = model.kmeans_step(x, c, mask)
    want = ref.kmeans_assign(x, c, mask)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-3)
